//! `pds serve` — a long-running concurrent ingest + query daemon.
//!
//! Four lanes share one process, coupled only through lock-free or
//! briefly-locked state:
//!
//! * **Ingest** ([`ingest`]): request handlers validate raw sample
//!   batches and `try_send` them into a bounded queue (a full queue is
//!   a typed `backpressure` error, never a block); one worker thread
//!   owns the [`Sparsifier`] and a live
//!   [`SparseStoreWriter`](crate::store::SparseStoreWriter), appending
//!   and durably checkpointing the manifest at every shard boundary —
//!   a killed daemon always leaves a CRC-clean, openable store.
//! * **Refresh** ([`refresh`]): a timer thread incrementally re-fits
//!   the model — only shards new since the last cycle are folded, then
//!   merged into the running partial via the PR 7
//!   [`PartialFit`](crate::distributed::PartialFit) law — publishes an
//!   immutable [`ModelSnapshot`](snapshot::ModelSnapshot) with a bumped
//!   version, and persists it as a `.pdsp` artifact next to the store
//!   manifest (the warm-start file).
//! * **Batch** ([`batcher`], private): every `query` / `query_batch`
//!   request parks in one shared lane; a worker coalesces whatever is
//!   in flight — across connections — into a SIMD panel (bounded by
//!   `batch_window` / `batch_max`) and demuxes results per request.
//!   The panel path *is* the per-sample path (a single query is a
//!   panel of one), so batching is bit-identical to one-at-a-time
//!   execution at every batch size and ISA tier.
//! * **Query**: handlers submit to the batch lane and answer from the
//!   `Arc`-swapped snapshot it executed against
//!   ([`snapshot::SnapshotCell`]) — queries never block on a refresh
//!   and never observe a half-written model.
//!
//! **Graceful degradation** is the design center: a failed refresh
//! marks the current snapshot `stale: true` and keeps serving it; a
//! failed ingest writer poisons only the ingest lane; malformed
//! requests get typed error codes ([`protocol`]); a connection beyond
//! the transport's worker-slot cap receives one typed `backpressure`
//! line and is closed (bounded resources, no silent hang); SIGTERM /
//! ctrl-c flush the writer and finalize the manifest before exit.
//!
//! **Warm restart**: starting the daemon on a directory that already
//! holds a live store resumes appending at its last durable checkpoint
//! and — when a persisted snapshot matches the configured task and
//! dimension — serves that model immediately at its pre-restart
//! version, instead of answering `no_model` until the first refresh.
//!
//! Transports: newline-delimited JSON over stdin/stdout
//! ([`run_pipe`] — the test- and CI-friendly mode), TCP
//! ([`run_tcp`], `--listen HOST:PORT`), or a Unix domain socket
//! ([`run_socket`], unix only). Both socket transports run a bounded
//! worker pool (`conn_slots`) instead of a thread per connection.

mod batcher;
pub mod ingest;
pub mod json;
pub mod protocol;
pub mod refresh;
pub mod snapshot;
mod transport;

#[cfg(unix)]
pub use transport::run_socket;
pub use transport::run_tcp;

use std::io::{BufRead, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::kmeans::KmeansOpts;
use crate::linalg::Mat;
use crate::metrics::ServeMetrics;
use crate::sampling::{Scheme, Sparsifier, SparsifyConfig};
use crate::sparse::Precision;
use crate::store::{SparseStoreWriter, StoreManifest, MANIFEST_FILE};

use self::batcher::{run_batch_worker, BatchQueue, Reply};
use self::ingest::{run_ingest_worker, IngestBatch, IngestShared};
use self::json::Json;
use self::protocol::{
    error_response, ok_response, Request, CODE_BACKPRESSURE, CODE_BAD_REQUEST, CODE_INTERNAL,
    CODE_NO_MODEL, CODE_SHUTDOWN, CODE_TIMEOUT,
};
use self::refresh::{run_refresh_worker, RefreshCtl, RefreshParams};
use self::snapshot::{ModelKind, ModelSnapshot, QueryResult, SnapshotCell};

/// Which model the daemon maintains and serves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeTask {
    /// Streaming PCA: queries project samples onto the fitted PCs.
    Pca,
    /// Streaming K-means: queries assign samples to the nearest center
    /// (with the Eq. 43 center-error bound where the theory applies).
    Kmeans,
}

impl ServeTask {
    /// Parse a `--task` value.
    pub fn parse(name: &str) -> Result<ServeTask> {
        match name {
            "pca" => Ok(ServeTask::Pca),
            "kmeans" => Ok(ServeTask::Kmeans),
            other => Err(Error::Invalid(format!("--task {other:?} (want kmeans|pca)"))),
        }
    }

    /// Stable lowercase name.
    pub fn name(&self) -> &'static str {
        match self {
            ServeTask::Pca => "pca",
            ServeTask::Kmeans => "kmeans",
        }
    }
}

/// Daemon configuration (fixed at start).
pub struct ServeConfig {
    /// Fresh directory for the live store (must not already hold a
    /// completed store).
    pub store_dir: PathBuf,
    /// Model to maintain.
    pub task: ServeTask,
    /// Original sample dimension — every ingest/query sample must have
    /// exactly this many entries.
    pub p: usize,
    /// Sparsifier configuration (gamma, transform, seed).
    pub scfg: SparsifyConfig,
    /// Element-sampling scheme.
    pub scheme: Scheme,
    /// Store value precision.
    pub precision: Precision,
    /// Apply the ROS preconditioner (false = the ablation arm).
    pub precondition: bool,
    /// Columns per store shard — also the checkpoint granularity.
    pub shard_cols: usize,
    /// PCA: components to keep.
    pub topk: usize,
    /// K-means: cluster count.
    pub k: usize,
    /// K-means: Lloyd options for the coreset solve.
    pub kmeans_opts: KmeansOpts,
    /// K-means: merge-and-reduce coreset node capacity.
    pub coreset_capacity: usize,
    /// Bounded ingest queue depth, in batches — the backpressure knob.
    pub queue_batches: usize,
    /// Periodic model-refresh interval.
    pub refresh_interval: Duration,
    /// Wait budget for blocking requests (`refresh`, `flush`, `query`).
    pub request_timeout: Duration,
    /// How long the batching lane waits for more in-flight queries to
    /// join a panel once the first one arrives.
    pub batch_window: Duration,
    /// Maximum samples coalesced into one query panel.
    pub batch_max: usize,
    /// Socket transports: bounded connection worker slots; a connection
    /// beyond the cap gets one typed `backpressure` line and is closed.
    pub conn_slots: usize,
}

impl ServeConfig {
    /// A config with the daemon defaults for `store_dir`, `task`, `p`.
    pub fn new(store_dir: PathBuf, task: ServeTask, p: usize) -> Self {
        ServeConfig {
            store_dir,
            task,
            p,
            scfg: SparsifyConfig {
                gamma: 0.2,
                transform: crate::transform::TransformKind::Hadamard,
                seed: 0,
            },
            scheme: Scheme::Precond,
            precision: Precision::F64,
            precondition: true,
            shard_cols: 1024,
            topk: 5,
            k: 5,
            kmeans_opts: KmeansOpts::default(),
            coreset_capacity: 256,
            queue_batches: 32,
            refresh_interval: Duration::from_secs(5),
            request_timeout: Duration::from_secs(30),
            batch_window: Duration::from_micros(100),
            batch_max: 64,
            conn_slots: 64,
        }
    }
}

/// State shared by every handler and worker thread.
struct Shared {
    task: ServeTask,
    p_orig: usize,
    queue_batches: usize,
    conn_slots: usize,
    timeout: Duration,
    metrics: Arc<ServeMetrics>,
    cell: Arc<SnapshotCell>,
    ingest: Arc<IngestShared>,
    refresh: Arc<RefreshCtl>,
    batcher: Arc<BatchQueue>,
    shutdown: Arc<AtomicBool>,
}

/// A running serve daemon: the ingest worker, the refresh loop, and the
/// shared state handlers answer from. Create [`Client`]s (one per
/// connection / test) with [`client`](Self::client); stop with
/// [`shutdown`](Self::shutdown), which flushes the writer and returns
/// the finalized manifest.
pub struct Daemon {
    shared: Arc<Shared>,
    tx: SyncSender<IngestBatch>,
    ingest_thread: JoinHandle<Result<StoreManifest>>,
    refresh_thread: JoinHandle<()>,
    batch_thread: JoinHandle<()>,
}

impl Daemon {
    /// Start the daemon: create the live store in `cfg.store_dir` (or
    /// resume a previous run's store at its last durable checkpoint)
    /// and spawn the ingest, refresh, and batch threads. When a
    /// persisted snapshot matching the configured task and dimension is
    /// found next to the store manifest, it is published immediately —
    /// the warm start — so the first query never sees `no_model` after
    /// a restart.
    pub fn start(cfg: ServeConfig) -> Result<Daemon> {
        if cfg.queue_batches == 0 {
            return Err(Error::Invalid("serve: queue_batches must be positive".into()));
        }
        if cfg.batch_max == 0 {
            return Err(Error::Invalid("serve: batch_max must be positive".into()));
        }
        if cfg.conn_slots == 0 {
            return Err(Error::Invalid("serve: conn_slots must be positive".into()));
        }
        let sp = Sparsifier::with_scheme(cfg.p, cfg.scfg, cfg.scheme)?;
        let writer = if cfg.store_dir.join(MANIFEST_FILE).exists() {
            // a previous run's live store: resume appending after its
            // last durable checkpoint (config mismatches are typed
            // errors inside reopen, never silent corruption)
            SparseStoreWriter::reopen(
                &cfg.store_dir,
                &sp,
                cfg.scfg,
                cfg.precondition,
                cfg.shard_cols,
                cfg.precision,
            )?
        } else {
            SparseStoreWriter::create(
                &cfg.store_dir,
                &sp,
                cfg.scfg,
                cfg.precondition,
                cfg.shard_cols,
            )?
            .with_precision(cfg.precision)
        };

        let metrics = Arc::new(ServeMetrics::new());
        let cell = Arc::new(SnapshotCell::new());
        // warm start: serve the last persisted model right away; a
        // damaged or mismatched artifact degrades to a cold start
        let initial_version = match ModelSnapshot::load(&cfg.store_dir) {
            Ok(Some(snap)) if snapshot_matches(&snap, cfg.task, cfg.p) => {
                let v = snap.version;
                cell.publish(snap);
                v
            }
            Ok(Some(_)) => {
                eprintln!(
                    "pds serve: ignoring persisted snapshot (task or dimension mismatch); \
                     cold start"
                );
                0
            }
            Ok(None) => 0,
            Err(e) => {
                eprintln!("pds serve: ignoring persisted snapshot ({e}); cold start");
                0
            }
        };
        let ingest_shared = Arc::new(IngestShared::new());
        let refresh_ctl = Arc::new(RefreshCtl::new());
        let batch_queue = Arc::new(BatchQueue::new());
        let shutdown = Arc::new(AtomicBool::new(false));

        let (tx, rx) = sync_channel::<IngestBatch>(cfg.queue_batches);
        let ingest_thread = {
            let (shared, m, stop) = (ingest_shared.clone(), metrics.clone(), shutdown.clone());
            let precondition = cfg.precondition;
            std::thread::Builder::new()
                .name("pds-serve-ingest".into())
                .spawn(move || run_ingest_worker(rx, sp, precondition, writer, shared, m, stop))?
        };
        let refresh_thread = {
            let params = RefreshParams {
                dir: cfg.store_dir.clone(),
                task: cfg.task,
                topk: cfg.topk,
                k: cfg.k,
                kmeans_opts: cfg.kmeans_opts,
                coreset_capacity: cfg.coreset_capacity,
                interval: cfg.refresh_interval,
                initial_version,
            };
            let (c, ctl, m, stop) =
                (cell.clone(), refresh_ctl.clone(), metrics.clone(), shutdown.clone());
            std::thread::Builder::new()
                .name("pds-serve-refresh".into())
                .spawn(move || run_refresh_worker(params, c, ctl, m, stop))?
        };
        let batch_thread = {
            let (q, c, m) = (batch_queue.clone(), cell.clone(), metrics.clone());
            let (window, batch_max) = (cfg.batch_window, cfg.batch_max);
            std::thread::Builder::new()
                .name("pds-serve-batch".into())
                .spawn(move || run_batch_worker(q, c, m, window, batch_max))?
        };

        let shared = Arc::new(Shared {
            task: cfg.task,
            p_orig: cfg.p,
            queue_batches: cfg.queue_batches,
            conn_slots: cfg.conn_slots,
            timeout: cfg.request_timeout,
            metrics,
            cell,
            ingest: ingest_shared,
            refresh: refresh_ctl,
            batcher: batch_queue,
            shutdown,
        });
        Ok(Daemon { shared, tx, ingest_thread, refresh_thread, batch_thread })
    }

    /// A request-handling client. Cheap to clone — each connection (or
    /// test thread) gets its own.
    pub fn client(&self) -> Client {
        Client { shared: self.shared.clone(), tx: self.tx.clone() }
    }

    /// The daemon's metrics registry (live; shared with all handlers).
    pub fn metrics(&self) -> &ServeMetrics {
        &self.shared.metrics
    }

    /// Graceful stop: raise the shutdown flag, let the ingest worker
    /// drain its backlog and finalize the store, join every worker.
    /// Returns the final manifest (or the ingest lane's first error)
    /// and the final metrics dump.
    pub fn shutdown(self) -> (Result<StoreManifest>, String) {
        // SeqCst: the shutdown flag orders the store against every
        // lane's subsequent load (all lanes poll it; cost is irrelevant
        // on this once-per-process path)
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.refresh.cv.notify_all();
        self.shared.ingest.cv.notify_all();
        self.shared.batcher.begin_shutdown();
        drop(self.tx);
        let manifest = match self.ingest_thread.join() {
            Ok(r) => r,
            Err(_) => Err(Error::Invalid("serve: ingest worker panicked".into())),
        };
        let _ = self.refresh_thread.join();
        let _ = self.batch_thread.join();
        let stats = self.shared.metrics.to_json();
        (manifest, stats)
    }
}

/// One protocol endpoint: parses request lines, dispatches them against
/// the daemon's shared state, and serializes responses. Every response
/// is a single JSON line; the boolean in [`handle_line`](Self::handle_line)'s
/// return is true when the request asked the daemon to shut down.
#[derive(Clone)]
pub struct Client {
    shared: Arc<Shared>,
    tx: SyncSender<IngestBatch>,
}

impl Client {
    /// Handle one request line; returns `(response_line, shutdown)`.
    pub fn handle_line(&self, line: &str) -> (String, bool) {
        // Relaxed: monotonic stats counter, no ordering with other data
        self.shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let request = match Request::parse(line) {
            Ok(r) => r,
            Err(e) => return (self.error(CODE_BAD_REQUEST, &e.to_string()), false),
        };
        match request {
            Request::Ingest { samples } => (self.handle_ingest(samples), false),
            Request::Query { sample } => (self.handle_query(sample), false),
            Request::QueryBatch { samples } => (self.handle_query_batch(samples), false),
            Request::Stats => (self.handle_stats(), false),
            Request::Refresh => (self.handle_refresh(), false),
            Request::Flush => (self.handle_flush(), false),
            Request::Shutdown => {
                // SeqCst: pairs with every lane's SeqCst poll of the
                // shutdown flag (see Daemon::shutdown)
                self.shared.shutdown.store(true, Ordering::SeqCst);
                self.shared.refresh.cv.notify_all();
                (ok_response(vec![]), true)
            }
        }
    }

    fn error(&self, code: &str, message: &str) -> String {
        // Relaxed: monotonic stats counter, no ordering with other data
        self.shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
        error_response(code, message)
    }

    fn handle_ingest(&self, samples: Vec<Vec<f64>>) -> String {
        let t0 = Instant::now();
        // SeqCst: must observe a shutdown stored by any thread before
        // this request was accepted
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return self.error(CODE_SHUTDOWN, "daemon is shutting down");
        }
        if let Some(msg) = self.shared.ingest.error_message() {
            return self.error(CODE_INTERNAL, &format!("ingest lane failed: {msg}"));
        }
        for (i, s) in samples.iter().enumerate() {
            if s.len() != self.shared.p_orig {
                return self.error(
                    CODE_BAD_REQUEST,
                    &format!(
                        "samples[{i}] has {} entries, the store dimension is {}",
                        s.len(),
                        self.shared.p_orig
                    ),
                );
            }
        }
        let n = samples.len();
        let data = Mat::from_fn(self.shared.p_orig, n, |i, j| samples[j][i]);
        // count under the progress lock so enqueued/absorbed and the
        // queue-depth gauge stay mutually consistent
        let mut pg = self.shared.ingest.lock_progress();
        if pg.finished {
            drop(pg);
            return self.error(CODE_SHUTDOWN, "ingest lane already finalized");
        }
        match self.tx.try_send(IngestBatch { data }) {
            Ok(()) => {
                pg.enqueued += 1;
                let depth = pg.enqueued.saturating_sub(pg.absorbed);
                // Relaxed: stats gauge; the progress lock above already
                // orders it against the enqueued/absorbed counters
                self.shared.metrics.queue_depth.store(depth, Ordering::Relaxed);
                drop(pg);
                let m = &self.shared.metrics;
                // Relaxed: monotonic stats counter, no ordering with other data
                m.ingested_rows.fetch_add(n as u64, Ordering::Relaxed);
                // Relaxed: monotonic stats counter, no ordering with other data
                m.ingested_batches.fetch_add(1, Ordering::Relaxed);
                m.ingest_latency.record(t0.elapsed());
                ok_response(vec![
                    ("rows", Json::Num(n as f64)),
                    ("queue_depth", Json::Num(depth as f64)),
                ])
            }
            Err(TrySendError::Full(_)) => {
                drop(pg);
                // Relaxed: monotonic stats counter, no ordering with other data
                self.shared.metrics.backpressure_rejections.fetch_add(1, Ordering::Relaxed);
                self.error(
                    CODE_BACKPRESSURE,
                    &format!(
                        "ingest queue full ({} batches); retry later",
                        self.shared.queue_batches
                    ),
                )
            }
            Err(TrySendError::Disconnected(_)) => {
                drop(pg);
                self.error(CODE_INTERNAL, "ingest lane terminated")
            }
        }
    }

    /// Map a non-answer reply from the batch lane onto a typed error
    /// response.
    fn batch_error(&self, reply: Reply) -> String {
        match reply {
            Reply::NoModel => {
                self.error(CODE_NO_MODEL, "no model published yet (ingest, then refresh)")
            }
            Reply::BadRequest(msg) => self.error(CODE_BAD_REQUEST, &msg),
            Reply::Internal(msg) => self.error(CODE_INTERNAL, msg),
            Reply::Timeout => {
                self.error(CODE_TIMEOUT, "query did not complete within the request timeout")
            }
            Reply::Shutdown => self.error(CODE_SHUTDOWN, "daemon is shutting down"),
            Reply::Answer { .. } => self.error(CODE_INTERNAL, "unexpected batch reply"),
        }
    }

    fn handle_query(&self, sample: Vec<f64>) -> String {
        let t0 = Instant::now();
        match self.shared.batcher.submit(vec![sample], self.shared.timeout) {
            Reply::Answer { snapshot, stale, mut results } => {
                let Some(result) = results.pop() else {
                    return self.error(CODE_INTERNAL, "batch lane returned no result");
                };
                let mut fields = vec![
                    ("model_version", Json::Num(snapshot.version as f64)),
                    ("stale", Json::Bool(stale)),
                    ("n", Json::Num(snapshot.n as f64)),
                ];
                push_result_fields(&mut fields, result);
                self.shared.metrics.query_latency.record(t0.elapsed());
                ok_response(fields)
            }
            other => self.batch_error(other),
        }
    }

    fn handle_query_batch(&self, samples: Vec<Vec<f64>>) -> String {
        let t0 = Instant::now();
        match self.shared.batcher.submit(samples, self.shared.timeout) {
            Reply::Answer { snapshot, stale, results } => {
                let items = results
                    .into_iter()
                    .map(|result| {
                        let mut fields = Vec::new();
                        push_result_fields(&mut fields, result);
                        Json::Obj(
                            fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
                        )
                    })
                    .collect();
                let fields = vec![
                    ("model_version", Json::Num(snapshot.version as f64)),
                    ("stale", Json::Bool(stale)),
                    ("n", Json::Num(snapshot.n as f64)),
                    ("results", Json::Arr(items)),
                ];
                self.shared.metrics.query_latency.record(t0.elapsed());
                ok_response(fields)
            }
            other => self.batch_error(other),
        }
    }

    fn handle_stats(&self) -> String {
        let pg = *self.shared.ingest.lock_progress();
        let ingest_error = match self.shared.ingest.error_message() {
            Some(m) => Json::Str(m).to_string(),
            None => "null".to_string(),
        };
        // one coherent read: version() + is_stale() as separate calls
        // could pair one snapshot's version with another's staleness if
        // a publish lands between them
        let (version, stale) = self.shared.cell.version_with_stale();
        format!(
            "{{\"ok\":true,\"task\":{},\"model_version\":{},\"stale\":{},\
             \"enqueued\":{},\"absorbed\":{},\"total_cols\":{},\"durable_cols\":{},\
             \"ingest_error\":{},\"metrics\":{}}}",
            Json::Str(self.shared.task.name().to_string()),
            version,
            stale,
            pg.enqueued,
            pg.absorbed,
            pg.total_cols,
            pg.durable_cols,
            ingest_error,
            self.shared.metrics.to_json()
        )
    }

    fn handle_refresh(&self) -> String {
        let goal = self.shared.refresh.request();
        match self.shared.refresh.wait_completed(goal, self.shared.timeout) {
            Ok(None) => {
                // coherent (version, stale) pair — see handle_stats
                let (version, stale) = self.shared.cell.version_with_stale();
                let fields = vec![
                    ("model_version", Json::Num(version as f64)),
                    ("stale", Json::Bool(stale)),
                ];
                ok_response(fields)
            }
            Ok(Some(msg)) => self.error(
                CODE_INTERNAL,
                &format!("refresh failed (still serving the previous snapshot): {msg}"),
            ),
            Err(()) => {
                self.error(CODE_TIMEOUT, "refresh did not complete within the request timeout")
            }
        }
    }

    fn handle_flush(&self) -> String {
        let goal = self.shared.ingest.lock_progress().enqueued;
        if !self.shared.ingest.wait_absorbed(goal, self.shared.timeout) {
            return self.error(CODE_TIMEOUT, "flush did not complete within the request timeout");
        }
        if let Some(msg) = self.shared.ingest.error_message() {
            return self.error(CODE_INTERNAL, &format!("ingest lane failed: {msg}"));
        }
        let pg = *self.shared.ingest.lock_progress();
        ok_response(vec![
            ("absorbed", Json::Num(pg.absorbed as f64)),
            ("total_cols", Json::Num(pg.total_cols as f64)),
            ("durable_cols", Json::Num(pg.durable_cols as f64)),
        ])
    }
}

/// Does a persisted snapshot fit this daemon's configuration? (Task and
/// original dimension must match; anything else is a different model.)
fn snapshot_matches(snap: &ModelSnapshot, task: ServeTask, p: usize) -> bool {
    let task_ok = match snap.kind {
        ModelKind::Pca(_) => task == ServeTask::Pca,
        ModelKind::Kmeans(_) => task == ServeTask::Kmeans,
    };
    task_ok && snap.dim() == p
}

/// Append one query result's task-specific response fields.
fn push_result_fields(fields: &mut Vec<(&'static str, Json)>, result: QueryResult) {
    match result {
        QueryResult::Projection { coords } => {
            fields.push(("coords", Json::Arr(coords.into_iter().map(Json::Num).collect())));
        }
        QueryResult::Assignment { cluster, distance2, center_bound } => {
            fields.push(("cluster", Json::Num(f64::from(cluster))));
            fields.push(("distance2", Json::Num(distance2)));
            // NaN (theory-not-applicable) serializes as null
            fields.push(("center_bound", Json::Num(center_bound)));
        }
    }
}

/// Signal plumbing: SIGTERM / SIGINT raise a flag the serve loops poll,
/// so shutdown always goes through the writer-flush path.
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    pub static TERMINATE: AtomicBool = AtomicBool::new(false);

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" fn on_signal(_signum: i32) {
        // async-signal-safe: one atomic store, nothing else.
        // SeqCst: a lock-free atomic store is the one async-signal-safe
        // publication primitive; pairs with the SeqCst load in raised()
        TERMINATE.store(true, Ordering::SeqCst);
    }

    extern "C" {
        // libc is always linked by std on unix; declaring the handler as
        // a typed fn pointer avoids any numeric cast
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    pub fn install() {
        // SAFETY: signal(2) is linked from libc (always present under
        // std on unix) and the declared signature matches its C
        // prototype, with the handler passed as a typed `extern "C"`
        // fn pointer of the required arity. `on_signal` is
        // async-signal-safe (a single lock-free atomic store, no
        // allocation, no locks), so installing it for SIGINT/SIGTERM
        // cannot introduce UB in interrupted contexts. The returned
        // previous-handler value is deliberately discarded.
        unsafe {
            let _ = signal(SIGINT, on_signal);
            let _ = signal(SIGTERM, on_signal);
        }
    }

    pub fn raised() -> bool {
        // SeqCst: pairs with the handler's SeqCst store; the watcher
        // must observe the flag promptly and in order with the
        // shutdown sequence it then starts
        TERMINATE.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod sig {
    pub fn install() {}
    pub fn raised() -> bool {
        false
    }
}

/// Spawn the watcher that turns a SIGTERM/SIGINT into a graceful stop:
/// raise the daemon's shutdown flag, wait for the ingest worker to
/// finalize the store, dump final metrics to stderr, exit 0. Returns
/// once the daemon shuts down normally instead.
fn spawn_signal_watcher(shared: Arc<Shared>) -> Result<()> {
    sig::install();
    std::thread::Builder::new()
        .name("pds-serve-signals".into())
        .spawn(move || loop {
            if sig::raised() {
                // SeqCst: pairs with every lane's SeqCst poll of the
                // shutdown flag (see Daemon::shutdown)
                shared.shutdown.store(true, Ordering::SeqCst);
                shared.refresh.cv.notify_all();
                // wait until the store is finalized before exiting
                let mut pg = shared.ingest.lock_progress();
                while !pg.finished {
                    pg = match shared.ingest.cv.wait_timeout(pg, Duration::from_millis(100)) {
                        Ok((g, _)) => g,
                        Err(poisoned) => poisoned.into_inner().0,
                    };
                }
                drop(pg);
                eprintln!("{}", shared.metrics.to_json());
                std::process::exit(0);
            }
            // SeqCst: must observe a normal shutdown stored by any
            // thread so the watcher exits instead of outliving the run
            if shared.shutdown.load(Ordering::SeqCst) {
                return; // normal shutdown path took over
            }
            std::thread::sleep(Duration::from_millis(50));
        })?;
    Ok(())
}

/// Run the daemon over stdin/stdout: one request line in, one response
/// line out, until EOF or a `shutdown` request; then flush + finalize
/// and dump final metrics to stderr. This is the transport the e2e
/// tests and the CI smoke job drive.
pub fn run_pipe(cfg: ServeConfig) -> Result<()> {
    let daemon = Daemon::start(cfg)?;
    spawn_signal_watcher(daemon.shared.clone())?;
    let client = daemon.client();
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    for line in stdin.lock().lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let (response, quit) = client.handle_line(&line);
        {
            let mut out = stdout.lock();
            out.write_all(response.as_bytes())?;
            out.write_all(b"\n")?;
            out.flush()?;
        }
        if quit {
            break;
        }
    }
    drop(client);
    let (manifest, stats) = daemon.shutdown();
    eprintln!("{stats}");
    manifest.map(|_| ())
}
