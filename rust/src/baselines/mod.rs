//! The comparison algorithms from the paper's evaluation:
//!
//! * [`FeatureExtraction`] — Boutsidis et al. [36]: compress with a single
//!   random sign matrix `Ω ∈ R^{m×p}`, K-means in `R^m`, centers lifted
//!   with `Ω⁺` (the provably *inconsistent* 1-pass center estimate the
//!   paper contrasts against in §VII.B).
//! * [`FeatureSelection`] — [36]: leverage-score row sampling from an
//!   approximate SVD (≥3 passes over the data).
//! * [`uniform_column_sampling`] — keep whole columns (Fig. 1 comparison).

mod feature_extraction;
mod feature_selection;

pub use feature_extraction::FeatureExtraction;
pub use feature_selection::FeatureSelection;

use crate::linalg::Mat;
use crate::rng::Pcg64;

/// Uniformly sample `c` columns (without replacement) of `x` — the
/// one-pass column-sampling scheme of Fig. 1. Returns the kept columns.
pub fn uniform_column_sampling(x: &Mat, c: usize, rng: &mut Pcg64) -> Mat {
    let n = x.cols();
    let c = c.min(n);
    let mut idx: Vec<u32> = (0..n as u32).collect();
    // partial Fisher–Yates
    for i in 0..c {
        let j = i + rng.next_range((n - i) as u32) as usize;
        idx.swap(i, j);
    }
    let mut out = Mat::zeros(x.rows(), c);
    for (t, &j) in idx[..c].iter().enumerate() {
        out.col_mut(t).copy_from_slice(x.col(j as usize));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn column_sampling_keeps_real_columns() {
        let mut rng = Pcg64::seed(1);
        let x = Mat::from_fn(4, 20, |i, j| (i * 100 + j) as f64);
        let s = uniform_column_sampling(&x, 5, &mut rng);
        assert_eq!(s.cols(), 5);
        for t in 0..5 {
            let found = (0..20).any(|j| {
                (0..4).all(|i| s.get(i, t) == x.get(i, j))
            });
            assert!(found, "sampled column {t} not found in source");
        }
    }

    #[test]
    fn column_sampling_caps_at_n() {
        let mut rng = Pcg64::seed(2);
        let x = Mat::zeros(3, 4);
        assert_eq!(uniform_column_sampling(&x, 10, &mut rng).cols(), 4);
    }
}
