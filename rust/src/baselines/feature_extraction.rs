//! Feature extraction (Boutsidis et al. [36]): `Z = Ω X` with a single
//! `m×p` random sign matrix; K-means runs in `R^m`.
//!
//! Center lifting uses `Ω⁺ = Ωᵀ(ΩΩᵀ)⁻¹` — the paper's §VII.B analysis
//! shows this estimator is *inconsistent* (`Ω⁺Ω ≠ I` has rank m < p), the
//! property our Fig. 9 experiment quantifies; a second pass over the
//! original data (like Algorithm 2) is required for usable centers.

use crate::error::Result;
use crate::kmeans::{kmeans_dense, KmeansOpts, KmeansResult};
use crate::linalg::{cholesky, cholesky_solve, Mat};
use crate::rng::Pcg64;

/// The single random sign projection shared by all samples.
pub struct FeatureExtraction {
    /// m×p sign matrix scaled by 1/√m.
    omega: Mat,
}

impl FeatureExtraction {
    /// Draw the `m×p` sign projection.
    pub fn new(p: usize, m: usize, rng: &mut Pcg64) -> Self {
        let scale = 1.0 / (m as f64).sqrt();
        let omega =
            Mat::from_fn(m, p, |_, _| if rng.next_f64() < 0.5 { scale } else { -scale });
        FeatureExtraction { omega }
    }

    /// Compressed dimension.
    pub fn m(&self) -> usize {
        self.omega.rows()
    }

    /// Compress: `Z = Ω X` (m×n).
    pub fn compress(&self, x: &Mat) -> Mat {
        self.omega.matmul(x)
    }

    /// K-means in the compressed domain; centers lifted with `Ω⁺`
    /// (1-pass — the inconsistent estimate).
    pub fn fit(&self, x: &Mat, k: usize, opts: KmeansOpts) -> Result<KmeansResult> {
        let z = self.compress(x);
        let res = kmeans_dense(&z, k, opts);
        let centers = self.lift_centers(&res.centers)?;
        Ok(KmeansResult { centers, ..res })
    }

    /// `Ω⁺ c = Ωᵀ (Ω Ωᵀ)⁻¹ c` per center column.
    pub fn lift_centers(&self, centers_z: &Mat) -> Result<Mat> {
        let m = self.omega.rows();
        let p = self.omega.cols();
        assert_eq!(centers_z.rows(), m);
        let gram = self.omega.matmul(&self.omega.transpose()); // m×m
        let l = cholesky(&gram)?;
        let mut out = Mat::zeros(p, centers_z.cols());
        for c in 0..centers_z.cols() {
            let y = cholesky_solve(&l, centers_z.col(c));
            let lifted = self.omega.matvec_transa(&y);
            out.col_mut(c).copy_from_slice(&lifted);
        }
        Ok(out)
    }

    /// 2-pass variant: after compressed-domain clustering, recompute
    /// centers as original-domain class means (extra pass).
    pub fn fit_two_pass(&self, x: &Mat, k: usize, opts: KmeansOpts) -> Result<KmeansResult> {
        let mut res = self.fit(x, k, opts)?;
        let p = x.rows();
        let mut sums = Mat::zeros(p, k);
        let mut counts = vec![0usize; k];
        for (j, &c) in res.assign.iter().enumerate() {
            counts[c as usize] += 1;
            let col = x.col(j);
            let s = sums.col_mut(c as usize);
            for i in 0..p {
                s[i] += col[i];
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                let inv = 1.0 / counts[c] as f64;
                let (s, dst) = (sums.col(c), res.centers.col_mut(c));
                for i in 0..p {
                    dst[i] = s[i] * inv;
                }
            }
        }
        Ok(res)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian_blobs;
    use crate::metrics::clustering_accuracy;

    #[test]
    fn clusters_well_in_compressed_domain() {
        let mut rng = Pcg64::seed(7);
        let d = gaussian_blobs(64, 500, 3, 0.05, &mut rng);
        let fe = FeatureExtraction::new(64, 16, &mut rng);
        let res = fe.fit(&d.data, 3, KmeansOpts { n_init: 3, ..Default::default() }).unwrap();
        let acc = clustering_accuracy(&res.assign, &d.labels, 3);
        assert!(acc > 0.95, "accuracy {acc}");
        assert_eq!(res.centers.rows(), 64);
    }

    #[test]
    fn lifted_centers_are_biased_two_pass_fixes() {
        // §VII.B: Ω⁺Ω-shrunk centers are worse than two-pass class means
        let mut rng = Pcg64::seed(9);
        let d = gaussian_blobs(64, 2000, 3, 0.05, &mut rng);
        let fe = FeatureExtraction::new(64, 10, &mut rng);
        let opts = KmeansOpts { n_init: 3, ..Default::default() };
        let one = fe.fit(&d.data, 3, opts).unwrap();
        let two = fe.fit_two_pass(&d.data, 3, opts).unwrap();
        let err = |res: &KmeansResult| -> f64 {
            let mut total = 0.0;
            for c in 0..3 {
                let mut best = f64::INFINITY;
                for t in 0..3 {
                    let dd: f64 = res
                        .centers
                        .col(c)
                        .iter()
                        .zip(d.centers.col(t))
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum();
                    best = best.min(dd);
                }
                total += best.sqrt();
            }
            total
        };
        assert!(
            err(&two) < 0.5 * err(&one),
            "two-pass centers should be much better: {} vs {}",
            err(&two),
            err(&one)
        );
    }

    #[test]
    fn compress_shape() {
        let mut rng = Pcg64::seed(1);
        let fe = FeatureExtraction::new(20, 5, &mut rng);
        let z = fe.compress(&Mat::zeros(20, 7));
        assert_eq!((z.rows(), z.cols()), (5, 7));
    }
}
