//! Feature selection (Boutsidis et al. [36]): sample `m` *rows* of `X`
//! with probabilities from approximate-SVD leverage scores, rescale, and
//! run K-means on the reduced m×n data.
//!
//! Pass accounting (paper Table II): one pass for the approximate SVD,
//! one to compute the sampling distribution + sample, one for clustering
//! features, and one more to obtain original-domain centers — this is the
//! most pass-hungry baseline, included to reproduce Figs. 7–9.

use crate::error::Result;
use crate::kmeans::{kmeans_dense, KmeansOpts, KmeansResult};
use crate::linalg::{leverage_scores, randomized_svd, Mat};
use crate::rng::{weighted_index, Pcg64};

/// Leverage-score row sampler + compressed-domain K-means.
pub struct FeatureSelection {
    /// Selected row indices (with replacement, as in [36]).
    rows: Vec<usize>,
    /// Per-selected-row rescale `1/sqrt(m·ℓ_j)`.
    scales: Vec<f64>,
}

impl FeatureSelection {
    /// Build the sampler from the data itself (approximate SVD with
    /// `rank = k` components).
    pub fn new(x: &Mat, m: usize, k: usize, rng: &mut Pcg64) -> Self {
        let svd = randomized_svd(x, k, 8, 2, rng.next_u64());
        let scores = leverage_scores(&svd.u, k);
        let mut rows = Vec::with_capacity(m);
        let mut scales = Vec::with_capacity(m);
        for _ in 0..m {
            let j = weighted_index(&scores, rng);
            rows.push(j);
            scales.push(1.0 / (m as f64 * scores[j].max(1e-300)).sqrt());
        }
        FeatureSelection { rows, scales }
    }

    /// Number of sampled rows.
    pub fn m(&self) -> usize {
        self.rows.len()
    }

    /// Reduce: pick + rescale the sampled rows (m×n).
    pub fn compress(&self, x: &Mat) -> Mat {
        let mut z = Mat::zeros(self.rows.len(), x.cols());
        for j in 0..x.cols() {
            let src = x.col(j);
            let dst = z.col_mut(j);
            for (t, (&r, &s)) in self.rows.iter().zip(&self.scales).enumerate() {
                dst[t] = src[r] * s;
            }
        }
        z
    }

    /// K-means on the reduced rows; centers recovered with the extra
    /// original-domain pass (there is no meaningful 1-pass center here:
    /// the reduced coordinates are a rescaled row subset).
    pub fn fit(&self, x: &Mat, k: usize, opts: KmeansOpts) -> Result<KmeansResult> {
        let z = self.compress(x);
        let res = kmeans_dense(&z, k, opts);
        // original-domain centers from assignments (extra pass)
        let p = x.rows();
        let mut sums = Mat::zeros(p, k);
        let mut counts = vec![0usize; k];
        for (j, &c) in res.assign.iter().enumerate() {
            counts[c as usize] += 1;
            let col = x.col(j);
            let s = sums.col_mut(c as usize);
            for i in 0..p {
                s[i] += col[i];
            }
        }
        let mut centers = Mat::zeros(p, k);
        for c in 0..k {
            if counts[c] > 0 {
                let inv = 1.0 / counts[c] as f64;
                let (s, dst) = (sums.col(c), centers.col_mut(c));
                for i in 0..p {
                    dst[i] = s[i] * inv;
                }
            }
        }
        Ok(KmeansResult { centers, ..res })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian_blobs;
    use crate::metrics::clustering_accuracy;

    #[test]
    fn selects_informative_rows() {
        // data with energy concentrated in rows 0..8: leverage sampling
        // must prefer those rows
        let mut rng = Pcg64::seed(3);
        let mut d = gaussian_blobs(32, 300, 3, 0.05, &mut rng);
        // zero out rows 8.. so information lives in the first 8 rows
        for j in 0..300 {
            let col = d.data.col_mut(j);
            for i in 8..32 {
                col[i] *= 0.001;
            }
        }
        let fs = FeatureSelection::new(&d.data, 10, 3, &mut rng);
        let informative = fs.rows.iter().filter(|&&r| r < 8).count();
        assert!(informative >= 8, "only {informative}/10 informative rows selected");
    }

    #[test]
    fn clusters_reasonably() {
        let mut rng = Pcg64::seed(5);
        let d = gaussian_blobs(64, 400, 3, 0.05, &mut rng);
        let fs = FeatureSelection::new(&d.data, 20, 3, &mut rng);
        let res = fs.fit(&d.data, 3, KmeansOpts { n_init: 3, ..Default::default() }).unwrap();
        let acc = clustering_accuracy(&res.assign, &d.labels, 3);
        assert!(acc > 0.9, "accuracy {acc}");
        assert_eq!(res.centers.rows(), 64);
    }

    #[test]
    fn compress_shape_and_scaling() {
        let mut rng = Pcg64::seed(7);
        let x = Mat::from_fn(10, 5, |i, j| (i + j) as f64);
        let fs = FeatureSelection::new(&x, 4, 2, &mut rng);
        let z = fs.compress(&x);
        assert_eq!((z.rows(), z.cols()), (4, 5));
    }
}
