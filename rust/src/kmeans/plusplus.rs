//! k-means++ seeding (Arthur & Vassilvitskii [45]) for dense matrices and
//! for sparsified chunks. The sparse variant runs D²-weighting directly on
//! the masked representation — exactly what Algorithm 1 line 5 does: the
//! seeding, like every other step, never touches the original data.

use crate::linalg::Mat;
use crate::rng::{weighted_index, Pcg64};
use crate::sparse::SparseChunk;

/// k-means++ on a dense matrix: returns p×k centers (copies of columns).
pub fn kmeans_pp_dense(x: &Mat, k: usize, rng: &mut Pcg64) -> Mat {
    let n = x.cols();
    let p = x.rows();
    assert!(n >= 1 && k >= 1);
    let mut centers = Mat::zeros(p, k);
    let first = rng.next_range(n as u32) as usize;
    centers.col_mut(0).copy_from_slice(x.col(first));
    let mut d2 = vec![0.0f64; n];
    for j in 0..n {
        d2[j] = dist2(x.col(j), centers.col(0));
    }
    for c in 1..k {
        let pick = weighted_index(&d2, rng);
        centers.col_mut(c).copy_from_slice(x.col(pick));
        if c + 1 < k {
            for j in 0..n {
                let d = dist2(x.col(j), centers.col(c));
                if d < d2[j] {
                    d2[j] = d;
                }
            }
        }
    }
    centers
}

#[inline]
fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Masked distance of a sparse column to a dense center (Eq. 36 for one
/// pair): `Σ_{j∈mask} (w_j − μ_j)²`. Two independent accumulators hide
/// the gather latency of `center[j]` (§Perf log).
#[inline]
pub(crate) fn masked_dist2(idx: &[u32], vals: &[f64], center: &[f64]) -> f64 {
    let mut s0 = 0.0;
    let mut s1 = 0.0;
    let pairs = idx.len() / 2;
    for t in 0..pairs {
        let j0 = idx[2 * t] as usize;
        let j1 = idx[2 * t + 1] as usize;
        let d0 = vals[2 * t] - center[j0];
        let d1 = vals[2 * t + 1] - center[j1];
        s0 += d0 * d0;
        s1 += d1 * d1;
    }
    if idx.len() % 2 == 1 {
        let last = idx.len() - 1;
        let d = vals[last] - center[idx[last] as usize];
        s0 += d * d;
    }
    s0 + s1
}

/// k-means++ on sparsified chunks: D²-weighted seeding with masked
/// distances, candidate centers are densified sparse columns *as-is*
/// (no `p/m` rescale). Rescaling the seeds plants large spikes at the
/// seed's kept coordinates; any sample whose mask covers a spike then
/// avoids that cluster forever, so the spike is never averaged away — a
/// self-reinforcing degenerate fixed point of the masked Lloyd update.
/// Unscaled seeds stay within the data's magnitude range and are washed
/// out after one update, matching the paper's "run k-means++ on the
/// sparse matrix" (Algorithm 1 line 5).
pub fn kmeans_pp_sparse(chunks: &[SparseChunk], k: usize, rng: &mut Pcg64) -> Mat {
    assert!(!chunks.is_empty());
    let p = chunks[0].p();
    let n: usize = chunks.iter().map(|c| c.n()).sum();
    assert!(n >= 1 && k >= 1);
    let col_of = |global: usize| -> (&SparseChunk, usize) {
        let mut g = global;
        for ch in chunks {
            if g < ch.n() {
                return (ch, g);
            }
            g -= ch.n();
        }
        unreachable!()
    };
    let densify = |global: usize, out: &mut [f64]| {
        out.fill(0.0);
        let (ch, i) = col_of(global);
        for (&j, &v) in ch.col_indices(i).iter().zip(ch.col_values(i)) {
            out[j as usize] = v;
        }
    };
    let mut centers = Mat::zeros(p, k);
    let first = rng.next_range(n as u32) as usize;
    densify(first, centers.col_mut(0));
    let mut d2 = vec![0.0f64; n];
    let mut g = 0usize;
    for ch in chunks {
        for i in 0..ch.n() {
            d2[g] = masked_dist2(ch.col_indices(i), ch.col_values(i), centers.col(0));
            g += 1;
        }
    }
    for c in 1..k {
        let pick = weighted_index(&d2, rng);
        densify(pick, centers.col_mut(c));
        if c + 1 < k {
            let mut g = 0usize;
            for ch in chunks {
                for i in 0..ch.n() {
                    let d = masked_dist2(ch.col_indices(i), ch.col_values(i), centers.col(c));
                    if d < d2[g] {
                        d2[g] = d;
                    }
                    g += 1;
                }
            }
        }
    }
    centers
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian_blobs;
    use crate::sampling::{Sparsifier, SparsifyConfig};
    use crate::transform::TransformKind;

    #[test]
    fn dense_seeds_are_data_columns() {
        let mut rng = Pcg64::seed(1);
        let d = gaussian_blobs(8, 100, 3, 0.1, &mut rng);
        let centers = kmeans_pp_dense(&d.data, 3, &mut rng);
        for c in 0..3 {
            let found = (0..100).any(|j| dist2(centers.col(c), d.data.col(j)) < 1e-20);
            assert!(found, "center {c} is not a data column");
        }
    }

    #[test]
    fn dense_seeds_spread_across_clusters() {
        let mut rng = Pcg64::seed(7);
        let d = gaussian_blobs(8, 300, 3, 0.02, &mut rng);
        // count how often all 3 seeds land in distinct true clusters
        let mut hits = 0;
        for s in 0..20u64 {
            let mut r = Pcg64::seed(s);
            let centers = kmeans_pp_dense(&d.data, 3, &mut r);
            let mut seen = [false; 3];
            for c in 0..3 {
                // nearest true center
                let mut best = (f64::INFINITY, 0usize);
                for t in 0..3 {
                    let dd = dist2(centers.col(c), d.centers.col(t));
                    if dd < best.0 {
                        best = (dd, t);
                    }
                }
                seen[best.1] = true;
            }
            if seen.iter().all(|&s| s) {
                hits += 1;
            }
        }
        assert!(hits >= 16, "++ seeding should usually hit all clusters: {hits}/20");
    }

    #[test]
    fn sparse_seeding_shapes_and_rescale() {
        let mut rng = Pcg64::seed(3);
        let d = gaussian_blobs(32, 200, 4, 0.1, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.25, transform: TransformKind::Hadamard, seed: 5 };
        let sp = Sparsifier::new(32, cfg).unwrap();
        let c0 = sp.compress_chunk(&d.data.col_range(0, 120), 0).unwrap();
        let c1 = sp.compress_chunk(&d.data.col_range(120, 200), 120).unwrap();
        let centers = kmeans_pp_sparse(&[c0.clone(), c1], 4, &mut rng);
        assert_eq!(centers.rows(), 32);
        assert_eq!(centers.cols(), 4);
        // each center has at most m nonzeros and unscaled data values
        let m = sp.m();
        for c in 0..4 {
            let nnz = centers.col(c).iter().filter(|&&v| v != 0.0).count();
            assert!(nnz <= m, "nnz {nnz} > m {m}");
        }
    }

    #[test]
    fn masked_dist_ignores_unsampled_coords() {
        let idx = [1u32, 3];
        let vals = [2.0, -1.0];
        let center = [100.0, 2.0, 100.0, -1.0, 100.0];
        assert_eq!(masked_dist2(&idx, &vals, &center), 0.0);
    }
}
