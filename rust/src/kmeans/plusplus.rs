//! k-means++ seeding (Arthur & Vassilvitskii [45]) for dense matrices and
//! for sparsified chunks. The sparse variant runs D²-weighting directly on
//! the masked representation — exactly what Algorithm 1 line 5 does: the
//! seeding, like every other step, never touches the original data.
//!
//! The sparse seeding is *source-driven*: it consumes any rewindable
//! [`SparseChunkSource`] (a memory-budgeted store reader included) in
//! whole passes, so no stage ever materializes the sparse matrix. The
//! picks are byte-identical to seeding over the equivalent in-memory
//! chunks — every step (the D² table, the RNG draw sequence, the
//! densified seeds) depends only on the global column order, never on
//! chunk boundaries.

use crate::error::{invalid, Result};
use crate::linalg::Mat;
use crate::rng::{weighted_index, Pcg64};
use crate::sparse::{SparseChunk, SparseChunkSource};

use super::center_step::{ChunkWalk, SliceWalk, SourceWalk};

/// k-means++ on a dense matrix: returns p×k centers (copies of columns).
pub fn kmeans_pp_dense(x: &Mat, k: usize, rng: &mut Pcg64) -> Mat {
    let n = x.cols();
    let p = x.rows();
    assert!(n >= 1 && k >= 1);
    let mut centers = Mat::zeros(p, k);
    let first = rng.next_range(n as u32) as usize;
    centers.col_mut(0).copy_from_slice(x.col(first));
    let mut d2 = vec![0.0f64; n];
    for j in 0..n {
        d2[j] = dist2(x.col(j), centers.col(0));
    }
    for c in 1..k {
        let pick = weighted_index(&d2, rng);
        centers.col_mut(c).copy_from_slice(x.col(pick));
        if c + 1 < k {
            for j in 0..n {
                let d = dist2(x.col(j), centers.col(c));
                if d < d2[j] {
                    d2[j] = d;
                }
            }
        }
    }
    centers
}

#[inline]
fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Masked distance of a sparse column to a dense center (Eq. 36 for one
/// pair): `Σ_{j∈mask} (w_j − μ_j)²`. Two independent accumulators hide
/// the gather latency of `center[j]` (§Perf log).
#[inline]
pub(crate) fn masked_dist2(idx: &[u32], vals: &[f64], center: &[f64]) -> f64 {
    let mut s0 = 0.0;
    let mut s1 = 0.0;
    let pairs = idx.len() / 2;
    for t in 0..pairs {
        let j0 = idx[2 * t] as usize;
        let j1 = idx[2 * t + 1] as usize;
        let d0 = vals[2 * t] - center[j0];
        let d1 = vals[2 * t + 1] - center[j1];
        s0 += d0 * d0;
        s1 += d1 * d1;
    }
    if idx.len() % 2 == 1 {
        let last = idx.len() - 1;
        let d = vals[last] - center[idx[last] as usize];
        s0 += d * d;
    }
    s0 + s1
}

/// Densify global column `target` of the walked stream into `out`
/// (zeros at unsampled coordinates). Stops the pass as soon as the
/// owning chunk has been visited.
fn densify_col(walk: &mut dyn ChunkWalk, target: usize, out: &mut [f64]) -> Result<()> {
    out.fill(0.0);
    let mut off = 0usize;
    let mut found = false;
    walk.walk(&mut |ch| {
        if target < off + ch.n() {
            let i = target - off;
            for (&j, &v) in ch.col_indices(i).iter().zip(ch.col_values(i)) {
                out[j as usize] = v;
            }
            found = true;
            return Ok(false); // stop the pass early
        }
        off += ch.n();
        Ok(true)
    })?;
    if !found {
        return invalid(format!("kmeans++: seed column {target} beyond end of stream ({off})"));
    }
    Ok(())
}

/// One D² pass: `init` overwrites the table (distances to the first
/// seed), otherwise entries only shrink (min against the new seed).
fn update_d2(walk: &mut dyn ChunkWalk, center: &[f64], d2: &mut [f64], init: bool) -> Result<()> {
    let mut g = 0usize;
    walk.walk(&mut |ch| {
        if g + ch.n() > d2.len() {
            return invalid(format!(
                "kmeans++: source yielded more than its {} hinted samples",
                d2.len()
            ));
        }
        for i in 0..ch.n() {
            let d = masked_dist2(ch.col_indices(i), ch.col_values(i), center);
            if init || d < d2[g] {
                d2[g] = d;
            }
            g += 1;
        }
        Ok(true)
    })
}

/// The walk-driven core of the sparse seeding. Candidate centers are
/// densified sparse columns *as-is* (no `p/m` rescale). Rescaling the
/// seeds plants large spikes at the seed's kept coordinates; any sample
/// whose mask covers a spike then avoids that cluster forever, so the
/// spike is never averaged away — a self-reinforcing degenerate fixed
/// point of the masked Lloyd update. Unscaled seeds stay within the
/// data's magnitude range and are washed out after one update, matching
/// the paper's "run k-means++ on the sparse matrix" (Algorithm 1 line 5).
pub(crate) fn kmeans_pp_walk(
    walk: &mut dyn ChunkWalk,
    p: usize,
    n: usize,
    k: usize,
    rng: &mut Pcg64,
) -> Result<Mat> {
    assert!(n >= 1 && k >= 1);
    let mut centers = Mat::zeros(p, k);
    let first = rng.next_range(n as u32) as usize;
    densify_col(walk, first, centers.col_mut(0))?;
    let mut d2 = vec![0.0f64; n];
    update_d2(walk, centers.col(0), &mut d2, true)?;
    for c in 1..k {
        let pick = weighted_index(&d2, rng);
        densify_col(walk, pick, centers.col_mut(c))?;
        if c + 1 < k {
            update_d2(walk, centers.col(c), &mut d2, false)?;
        }
    }
    Ok(centers)
}

/// k-means++ on sparsified data from any rewindable source: D²-weighted
/// seeding with masked distances, in whole passes over the source — the
/// sparse matrix is never materialized. Byte-identical center picks to
/// [`kmeans_pp_sparse_chunks`] on the same data for a given RNG state.
pub fn kmeans_pp_sparse(
    source: &mut dyn SparseChunkSource,
    k: usize,
    rng: &mut Pcg64,
) -> Result<Mat> {
    let p = source.p();
    let n = match source.n_hint() {
        Some(n) => n,
        None => {
            let mut n = 0usize;
            SourceWalk::new(&mut *source).walk(&mut |c| {
                n += c.n();
                Ok(true)
            })?;
            n
        }
    };
    if n == 0 {
        return invalid("kmeans++: source is empty");
    }
    kmeans_pp_walk(&mut SourceWalk::new(source), p, n, k, rng)
}

/// k-means++ over in-memory sparsified chunks (ordered by `start_col`):
/// the borrowing fast path of [`kmeans_pp_sparse`] — same picks, no
/// source indirection.
pub fn kmeans_pp_sparse_chunks(chunks: &[SparseChunk], k: usize, rng: &mut Pcg64) -> Mat {
    assert!(!chunks.is_empty());
    let p = chunks[0].p();
    let n: usize = chunks.iter().map(|c| c.n()).sum();
    kmeans_pp_walk(&mut SliceWalk(chunks), p, n, k, rng)
        .expect("in-memory seeding cannot fail")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian_blobs;
    use crate::sampling::{Sparsifier, SparsifyConfig};
    use crate::sparse::SparseVecSource;
    use crate::transform::TransformKind;

    #[test]
    fn dense_seeds_are_data_columns() {
        let mut rng = Pcg64::seed(1);
        let d = gaussian_blobs(8, 100, 3, 0.1, &mut rng);
        let centers = kmeans_pp_dense(&d.data, 3, &mut rng);
        for c in 0..3 {
            let found = (0..100).any(|j| dist2(centers.col(c), d.data.col(j)) < 1e-20);
            assert!(found, "center {c} is not a data column");
        }
    }

    #[test]
    fn dense_seeds_spread_across_clusters() {
        let mut rng = Pcg64::seed(7);
        let d = gaussian_blobs(8, 300, 3, 0.02, &mut rng);
        // count how often all 3 seeds land in distinct true clusters
        let mut hits = 0;
        for s in 0..20u64 {
            let mut r = Pcg64::seed(s);
            let centers = kmeans_pp_dense(&d.data, 3, &mut r);
            let mut seen = [false; 3];
            for c in 0..3 {
                // nearest true center
                let mut best = (f64::INFINITY, 0usize);
                for t in 0..3 {
                    let dd = dist2(centers.col(c), d.centers.col(t));
                    if dd < best.0 {
                        best = (dd, t);
                    }
                }
                seen[best.1] = true;
            }
            if seen.iter().all(|&s| s) {
                hits += 1;
            }
        }
        assert!(hits >= 16, "++ seeding should usually hit all clusters: {hits}/20");
    }

    #[test]
    fn sparse_seeding_shapes_and_rescale() {
        let mut rng = Pcg64::seed(3);
        let d = gaussian_blobs(32, 200, 4, 0.1, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.25, transform: TransformKind::Hadamard, seed: 5 };
        let sp = Sparsifier::new(32, cfg).unwrap();
        let c0 = sp.compress_chunk(&d.data.col_range(0, 120), 0).unwrap();
        let c1 = sp.compress_chunk(&d.data.col_range(120, 200), 120).unwrap();
        let centers = kmeans_pp_sparse_chunks(&[c0.clone(), c1], 4, &mut rng);
        assert_eq!(centers.rows(), 32);
        assert_eq!(centers.cols(), 4);
        // each center has at most m nonzeros and unscaled data values
        let m = sp.m();
        for c in 0..4 {
            let nnz = centers.col(c).iter().filter(|&&v| v != 0.0).count();
            assert!(nnz <= m, "nnz {nnz} > m {m}");
        }
    }

    #[test]
    fn source_seeding_is_byte_identical_to_chunk_seeding() {
        // the satellite contract: the SparseChunkSource signature keeps
        // byte-identical center picks for the in-memory case — at every
        // chunk granularity
        let mut rng = Pcg64::seed(13);
        let d = gaussian_blobs(32, 260, 4, 0.1, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.25, transform: TransformKind::Hadamard, seed: 8 };
        let sp = Sparsifier::new(32, cfg).unwrap();
        let whole = sp.compress_chunk(&d.data, 0).unwrap();
        let mut r0 = Pcg64::seed(99);
        let base = kmeans_pp_sparse_chunks(&[whole.clone()], 4, &mut r0);
        for bounds in [vec![0usize, 260], vec![0, 50, 260], vec![0, 1, 2, 130, 260]] {
            let pieces: Vec<SparseChunk> = bounds
                .windows(2)
                .map(|w| sp.compress_chunk(&d.data.col_range(w[0], w[1]), w[0]).unwrap())
                .collect();
            let mut src = SparseVecSource::new(pieces).unwrap();
            let mut r1 = Pcg64::seed(99);
            let centers = kmeans_pp_sparse(&mut src, 4, &mut r1).unwrap();
            for (a, b) in centers.as_slice().iter().zip(base.as_slice()) {
                assert_eq!(a.to_bits(), b.to_bits(), "bounds {bounds:?}");
            }
        }
    }

    #[test]
    fn masked_dist_ignores_unsampled_coords() {
        let idx = [1u32, 3];
        let vals = [2.0, -1.0];
        let center = [100.0, 2.0, 100.0, -1.0, 100.0];
        assert_eq!(masked_dist2(&idx, &vals, &center), 0.0);
    }
}
