//! Two-pass sparsified K-means — paper Algorithm 2.
//!
//! Pass 1 is Algorithm 1 (assignments + centers from the sparse stream).
//! Pass 2 revisits the *original* data once: centers are re-computed as
//! exact class means of assigned samples, and samples are re-assigned to
//! the pass-1 center estimates in the original domain. The same
//! extra-pass applies to the feature-extraction/selection baselines
//! (whose 1-pass centers live in a compressed domain and are unusable).

use crate::linalg::Mat;

use super::dense::assign_dense;
use super::KmeansResult;

/// Algorithm 2 lines 3–10 given in-memory original data.
/// `one_pass` is the Algorithm 1 output (original-domain centers).
pub fn two_pass_refine(x: &Mat, one_pass: &KmeansResult) -> KmeansResult {
    let k = one_pass.centers.cols();
    let p = x.rows();
    let n = x.cols();
    assert_eq!(one_pass.assign.len(), n);
    // centers: exact means of pass-1 assignment groups, in original domain
    let mut sums = Mat::zeros(p, k);
    let mut counts = vec![0usize; k];
    for (j, &c) in one_pass.assign.iter().enumerate() {
        counts[c as usize] += 1;
        let col = x.col(j);
        let s = sums.col_mut(c as usize);
        for i in 0..p {
            s[i] += col[i];
        }
    }
    let mut centers = one_pass.centers.clone();
    for c in 0..k {
        if counts[c] > 0 {
            let inv = 1.0 / counts[c] as f64;
            let (s, dst) = (sums.col(c), centers.col_mut(c));
            for i in 0..p {
                dst[i] = s[i] * inv;
            }
        }
    }
    // assignments: nearest pass-1 center estimate in the original domain
    let (assign, objective) = assign_dense(x, &one_pass.centers);
    KmeansResult {
        centers,
        assign,
        objective,
        iterations: one_pass.iterations,
        converged: one_pass.converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian_blobs;
    use crate::kmeans::{KmeansOpts, SparsifiedKmeans};
    use crate::metrics::clustering_accuracy;
    use crate::rng::Pcg64;
    use crate::sampling::SparsifyConfig;
    use crate::transform::TransformKind;

    #[test]
    fn two_pass_at_least_as_accurate() {
        let mut rng = Pcg64::seed(8);
        let d = gaussian_blobs(64, 1200, 3, 0.25, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.12, transform: TransformKind::Hadamard, seed: 2 };
        let sk = SparsifiedKmeans::new(cfg, 3, KmeansOpts { n_init: 6, ..Default::default() });
        let one = sk.fit_dense(&d.data).unwrap();
        let two = two_pass_refine(&d.data, &one);
        let a1 = clustering_accuracy(&one.assign, &d.labels, 3);
        let a2 = clustering_accuracy(&two.assign, &d.labels, 3);
        assert!(a2 >= a1 - 0.02, "two-pass {a2} vs one-pass {a1}");
        assert_eq!(two.centers.rows(), 64);
    }

    #[test]
    fn two_pass_centers_are_exact_class_means() {
        let mut rng = Pcg64::seed(10);
        let d = gaussian_blobs(16, 200, 2, 0.1, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.4, transform: TransformKind::Hadamard, seed: 3 };
        let sk = SparsifiedKmeans::new(cfg, 2, KmeansOpts::default());
        let one = sk.fit_dense(&d.data).unwrap();
        let two = two_pass_refine(&d.data, &one);
        // recompute means directly from pass-1 assignment
        for c in 0..2 {
            let members: Vec<usize> =
                (0..200).filter(|&j| one.assign[j] == c as u32).collect();
            if members.is_empty() {
                continue;
            }
            for i in 0..16 {
                let want: f64 =
                    members.iter().map(|&j| d.data.get(i, j)).sum::<f64>() / members.len() as f64;
                assert!((two.centers.get(i, c) - want).abs() < 1e-12);
            }
        }
    }
}
