//! Sparsified K-means — paper Algorithm 1.
//!
//! Operates entirely on [`SparseChunk`]s (preconditioned + sampled data):
//! k-means++ seeding on the sparse matrix, masked-distance assignments
//! (Eq. 36), entry-wise masked center averaging (Eq. 39), and a final
//! unmix `μ = (HD)ᵀ μ'` (Eq. 32). One pass over the data produces both
//! assignments *and* original-domain centers — the paper's headline
//! property.
//!
//! The Lloyd iteration is **source-driven**: every step (seeding,
//! assignment, center accumulation) is a whole-pass fold over a
//! rewindable chunk stream through the [`CenterStep`](super::CenterStep)
//! kernel, so the fit never requires the sparse matrix to be resident.
//! [`fit_chunks`](SparsifiedKmeans::fit_chunks) walks in-memory slices
//! (the streaming drivers' path);
//! [`fit_source`](SparsifiedKmeans::fit_source) walks any
//! [`SparseChunkSource`] — with a memory-budgeted
//! [`SparseStoreReader`](crate::store::SparseStoreReader) the whole fit
//! is out-of-core at one sparse pass per Lloyd iteration. Both paths are
//! bitwise identical to each other for every worker count and chunk
//! granularity (see `CenterStep`'s invariants), and the fit additionally
//! evaluates the paper's per-step center-error guarantee
//! ([`estimators::center_error_bound`](crate::estimators::center_error_bound))
//! at each iteration's observed cluster sizes.
//!
//! Restarts (`KmeansOpts::n_init`) run over seeded sub-RNG streams and
//! may fan out across threads
//! ([`with_restart_workers`](SparsifiedKmeans::with_restart_workers)):
//! each restart is bitwise deterministic given its stream and the
//! best-inertia merge visits restarts in index order, so the selected
//! model is identical for every restart worker count.

use std::ops::Range;
use std::sync::OnceLock;

use crate::error::{invalid, Result};
use crate::linalg::Mat;
use crate::parallel;
use crate::rng::Pcg64;
use crate::sampling::{Sparsifier, SparsifyConfig};
use crate::simd::Isa;
use crate::sparse::{Precision, SparseChunk, SparseChunkSource};

use super::center_step::{CenterStep, ChunkWalk, SliceWalk, SourceWalk};
use super::plusplus::{kmeans_pp_walk, masked_dist2};
use super::{KmeansOpts, KmeansResult};

/// Failure probability δ at which the per-iteration center-error bound
/// ([`SparsifiedModel::center_bound`]) is evaluated.
pub const CENTER_BOUND_DELTA: f64 = 1e-3;

/// Strategy for the per-chunk assignment step — the pipeline hot spot.
/// Implemented natively ([`sparsified`](self)) and by the PJRT runtime
/// (`runtime::XlaEngine`) executing the AOT Pallas `assign` graph.
///
/// `Sync` is part of the contract: the parallel multi-restart path shares
/// one assigner across restart threads (engines keep interior state
/// behind a lock).
pub trait SparseAssigner: Sync {
    /// Assign each column of `chunk` to its nearest center (centers live
    /// in the preconditioned domain, `p × K`). Returns per-column cluster
    /// ids and the summed min masked distance (the Eq. 34 objective).
    fn assign(&self, chunk: &SparseChunk, centers: &Mat) -> Result<(Vec<u32>, f64)>;

    /// Assign each column of `chunk`, writing cluster ids into `out` and
    /// each column's min masked distance into `dist` (both of length
    /// `chunk.n()`). `workers` is a parallelism hint an implementation
    /// may ignore. The default forwards to [`assign`](Self::assign) and
    /// recomputes the per-column distances serially.
    fn assign_into(
        &self,
        chunk: &SparseChunk,
        centers: &Mat,
        workers: usize,
        out: &mut [u32],
        dist: &mut [f64],
    ) -> Result<()> {
        let _ = workers;
        let (ids, _obj) = self.assign(chunk, centers)?;
        debug_assert_eq!(ids.len(), chunk.n());
        for i in 0..chunk.n() {
            out[i] = ids[i];
            dist[i] = masked_dist2(
                chunk.col_indices(i),
                chunk.col_values(i),
                centers.col(ids[i] as usize),
            );
        }
        Ok(())
    }

    /// Human-readable engine name (for experiment tables).
    fn name(&self) -> &'static str {
        "native"
    }
}

/// Measured serial→parallel crossover: the smallest per-worker column
/// slice worth a scoped-thread spawn, per (precision, ISA) mode. Policy:
/// a worker's slice should cost ≥ ~10× the ~10 µs spawn+join overhead.
/// On the §assignment bench workload (digits, m=51, K=3) the scalar
/// kernel runs ~109 ns/col and the AVX2 panel kernel ~56 ns/col
/// (`BENCH_hotpaths.json`), giving ~1k and ~2k columns respectively.
/// Precision does not move the crossover — `f32`-stored chunks run the
/// same `f64` kernels after exact widening.
fn measured_cols_per_worker(precision: Precision, isa: Isa) -> usize {
    let _ = precision;
    match isa {
        // the assignment kernel has no SSE2 variant (falls back to
        // scalar), so SSE2 shares the scalar crossover
        Isa::Scalar | Isa::Sse2 => 1024,
        Isa::Avx2 => 2048,
    }
}

/// Parse a `PDS_ASSIGN_COLS_PER_WORKER` override (must be a positive
/// integer; anything else warns and is ignored). Split out from the env
/// read so it is unit-testable without racing the process environment.
pub(crate) fn parse_assign_cols_override(raw: Option<&str>) -> Option<usize> {
    let s = raw?.trim();
    match s.parse::<usize>() {
        Ok(v) if v > 0 => Some(v),
        _ => {
            eprintln!(
                "warning: PDS_ASSIGN_COLS_PER_WORKER={s:?} is not a positive integer; \
                 using the measured crossover"
            );
            None
        }
    }
}

fn env_assign_cols_override() -> Option<usize> {
    static CACHE: OnceLock<Option<usize>> = OnceLock::new();
    *CACHE.get_or_init(|| {
        parse_assign_cols_override(std::env::var("PDS_ASSIGN_COLS_PER_WORKER").ok().as_deref())
    })
}

/// Resolved per-chunk assignment strategy: the scalar center-major loop,
/// or the AVX2 panel kernel over 4-center groups.
enum AssignKernel {
    Scalar,
    /// `panel[g*p*4 ..][j*4 + c]` = coordinate `j` of center `4g + c`;
    /// lanes past `k` in the last group are zero (computed, never
    /// scanned by the argmin).
    Panel { panel: Vec<f64>, k: usize, isa: Isa },
}

fn build_assign_kernel(centers: &Mat, isa: Isa) -> AssignKernel {
    if isa < Isa::Avx2 {
        // no SSE2 assignment variant: 2 lanes don't cover the 2 loads +
        // broadcast per slot, and the scalar loop is already SSE2 code
        return AssignKernel::Scalar;
    }
    let p = centers.rows();
    let k = centers.cols();
    let groups = (k + 3) / 4;
    let mut panel = vec![0.0f64; groups * p * 4];
    for c in 0..k {
        let dst = &mut panel[(c / 4) * p * 4..];
        let lane = c % 4;
        for (j, &v) in centers.col(c).iter().enumerate() {
            dst[j * 4 + lane] = v;
        }
    }
    AssignKernel::Panel { panel, k, isa }
}

/// Assignment kernel over one contiguous column range. Both arms visit
/// centers in index order with a strict `<`, so the first of tied
/// minima wins — and the panel kernel's distances are bitwise equal to
/// the scalar chain (see `crate::simd`), so the two arms agree exactly.
fn assign_range(
    chunk: &SparseChunk,
    centers: &Mat,
    kernel: &AssignKernel,
    r: Range<usize>,
    out: &mut [u32],
    dist: &mut [f64],
) {
    match kernel {
        AssignKernel::Scalar => {
            let k = centers.cols();
            for (local, i) in r.enumerate() {
                let idx = chunk.col_indices(i);
                let vals = chunk.col_values(i);
                let mut best = f64::INFINITY;
                let mut arg = 0u32;
                for c in 0..k {
                    let d = masked_dist2(idx, vals, centers.col(c));
                    if d < best {
                        best = d;
                        arg = c as u32;
                    }
                }
                out[local] = arg;
                dist[local] = best;
            }
        }
        AssignKernel::Panel { panel, k, isa } => {
            let group_len = centers.rows() * 4;
            let groups = panel.len() / group_len;
            let mut d4 = [0.0f64; 4];
            for (local, i) in r.enumerate() {
                let idx = chunk.col_indices(i);
                let vals = chunk.col_values(i);
                let mut best = f64::INFINITY;
                let mut arg = 0u32;
                for g in 0..groups {
                    crate::simd::masked_dist2_x4(
                        *isa,
                        idx,
                        vals,
                        &panel[g * group_len..(g + 1) * group_len],
                        &mut d4,
                    );
                    let lanes = (*k - 4 * g).min(4);
                    for (c, &d) in d4.iter().take(lanes).enumerate() {
                        if d < best {
                            best = d;
                            arg = (4 * g + c) as u32;
                        }
                    }
                }
                out[local] = arg;
                dist[local] = best;
            }
        }
    }
}

/// Pure-Rust masked-distance assigner. Traverses the m kept indices per
/// sample instead of masking dense panels; on AVX2 it scores 4 centers
/// at once from a transposed center panel with *broadcast* values —
/// gather-based K-simultaneous forms were measured slower than scalar
/// (centers are L1-resident), which is also why the single-center
/// distance in the k-means++ seeding stays scalar.
///
/// Construct with [`new`](Self::new); the builders pin the fan-out
/// crossover ([`with_cols_per_worker`](Self::with_cols_per_worker)) or
/// the ISA tier ([`with_isa`](Self::with_isa)) — every configuration
/// produces bitwise-identical output.
pub struct NativeAssigner {
    cols_per_worker: Option<usize>,
    isa: Option<Isa>,
}

impl NativeAssigner {
    /// Default assigner: ISA from [`crate::simd::active`], fan-out
    /// crossover from `PDS_ASSIGN_COLS_PER_WORKER` or the measured
    /// per-(precision, ISA) table.
    pub const fn new() -> Self {
        NativeAssigner { cols_per_worker: None, isa: None }
    }

    /// Pin the serial-fallback threshold: [`assign_into`] only fans out
    /// when every worker gets at least this many columns. Takes
    /// precedence over the `PDS_ASSIGN_COLS_PER_WORKER` env var and the
    /// measured table.
    ///
    /// [`assign_into`]: SparseAssigner::assign_into
    pub fn with_cols_per_worker(mut self, cols: usize) -> Self {
        self.cols_per_worker = Some(cols.max(1));
        self
    }

    /// Pin the ISA tier (clamped to what the CPU supports). Results are
    /// bitwise identical across tiers; this exists for tests and A/B
    /// timing.
    pub fn with_isa(mut self, isa: Isa) -> Self {
        self.isa = Some(isa);
        self
    }

    fn isa(&self) -> Isa {
        self.isa.unwrap_or_else(crate::simd::active).min(crate::simd::detect())
    }

    fn cols_per_worker(&self, precision: Precision, isa: Isa) -> usize {
        self.cols_per_worker
            .or_else(env_assign_cols_override)
            .unwrap_or_else(|| measured_cols_per_worker(precision, isa))
    }
}

impl Default for NativeAssigner {
    fn default() -> Self {
        Self::new()
    }
}

impl SparseAssigner for NativeAssigner {
    fn assign(&self, chunk: &SparseChunk, centers: &Mat) -> Result<(Vec<u32>, f64)> {
        let isa = self.isa();
        let kernel = build_assign_kernel(centers, isa);
        let n = chunk.n();
        let mut assign = vec![0u32; n];
        let mut dist = vec![0.0f64; n];
        assign_range(chunk, centers, &kernel, 0..n, &mut assign, &mut dist);
        let obj = dist.iter().sum();
        Ok((assign, obj))
    }

    /// Sample-partitioned parallel assignment: each worker owns a
    /// contiguous column range and its matching output slices, so every
    /// per-sample result is computed exactly once by the same kernel as
    /// the serial path — bitwise identical for every worker count.
    fn assign_into(
        &self,
        chunk: &SparseChunk,
        centers: &Mat,
        workers: usize,
        out: &mut [u32],
        dist: &mut [f64],
    ) -> Result<()> {
        let n = chunk.n();
        debug_assert_eq!(out.len(), n);
        debug_assert_eq!(dist.len(), n);
        let isa = self.isa();
        let kernel = build_assign_kernel(centers, isa);
        // below the measured crossover the scoped-thread spawn overhead
        // beats the per-column work — fall back to fewer (or zero)
        // forks; the result is bitwise identical either way
        let min_cols = self.cols_per_worker(chunk.precision(), isa);
        let eff_workers = workers.min(n / min_cols).max(1);
        let ranges = parallel::split_ranges(n, eff_workers);
        if ranges.len() <= 1 {
            assign_range(chunk, centers, &kernel, 0..n, out, dist);
            return Ok(());
        }
        // carve the output buffers into per-range slices
        let mut jobs: Vec<(Range<usize>, &mut [u32], &mut [f64])> =
            Vec::with_capacity(ranges.len());
        let (mut rest_out, mut rest_dist) = (out, dist);
        for r in ranges {
            let len = r.len();
            let (o, ro) = std::mem::take(&mut rest_out).split_at_mut(len);
            let (d, rd) = std::mem::take(&mut rest_dist).split_at_mut(len);
            rest_out = ro;
            rest_dist = rd;
            jobs.push((r, o, d));
        }
        let kernel = &kernel;
        crossbeam_utils::thread::scope(|scope| {
            let mut iter = jobs.into_iter();
            let first = iter.next().expect("len > 1");
            let handles: Vec<_> = iter
                .map(|(r, o, d)| {
                    scope.spawn(move |_| assign_range(chunk, centers, kernel, r, o, d))
                })
                .collect();
            let (r, o, d) = first;
            assign_range(chunk, centers, kernel, r, o, d);
            for h in handles {
                h.join().expect("assign worker panicked");
            }
        })
        .expect("assign scope panicked");
        Ok(())
    }
}

/// Accumulate one chunk's contribution to the masked center update
/// (Eq. 39): `sums[j,k] += w_ij`, `counts[j,k] += 1` over kept entries of
/// samples assigned to `k` — one fused pass over each column's indices.
/// This is the serial reference kernel; the production fold is
/// [`CenterStep`](super::CenterStep), which is bitwise identical to it
/// at every worker count and chunk granularity.
pub fn accumulate_center_update(
    chunk: &SparseChunk,
    assign: &[u32],
    sums: &mut Mat,
    counts: &mut Mat,
) {
    debug_assert_eq!(assign.len(), chunk.n());
    for i in 0..chunk.n() {
        let c = assign[i] as usize;
        let scol = sums.col_mut(c);
        let ccol = counts.col_mut(c);
        for (&j, &v) in chunk.col_indices(i).iter().zip(chunk.col_values(i)) {
            scol[j as usize] += v;
            ccol[j as usize] += 1.0;
        }
    }
}

/// Solve the diagonal system of Eq. (39)/(40): `μ'_jk = sums/counts` where
/// observed; coordinates never sampled within a cluster keep `prev`'s
/// entry (the paper removes them from the system — equivalent to not
/// moving that coordinate).
pub fn solve_centers(sums: &Mat, counts: &Mat, prev: &Mat) -> Mat {
    let (p, k) = (sums.rows(), sums.cols());
    let mut out = Mat::zeros(p, k);
    for c in 0..k {
        let (s, cnt, pv, dst) = (sums.col(c), counts.col(c), prev.col(c), out.col_mut(c));
        for j in 0..p {
            dst[j] = if cnt[j] > 0.0 { s[j] / cnt[j] } else { pv[j] };
        }
    }
    out
}

/// The fitted sparsified model: result plus the preconditioned-domain
/// centers (useful for resuming / streaming assignment of new data) and
/// the per-iteration center-error bound.
pub struct SparsifiedModel {
    /// The fitted clustering (centers in the original domain).
    pub result: KmeansResult,
    /// Centers in the preconditioned (padded) domain, p_work × K.
    pub centers_precond: Mat,
    /// The paper's per-step center-estimator guarantee, evaluated at each
    /// Lloyd iteration of the winning restart: entry `t` is the worst
    /// cluster's Eq. 43 deviation bound
    /// ([`estimators::center_error_bound`](crate::estimators::center_error_bound)
    /// at δ = [`CENTER_BOUND_DELTA`]) given iteration `t`'s observed
    /// cluster sizes. Small values mean the masked averaging of Eq. 39
    /// was provably close to plain class means at every step. The bound
    /// is uniform-scheme theory: fits over weighted (hybrid) chunks
    /// record `NaN` per iteration instead of an unbacked number.
    pub center_bound: Vec<f64>,
}

/// Sparsified K-means (Algorithm 1).
#[derive(Clone, Copy)]
pub struct SparsifiedKmeans {
    /// Compression configuration (used by [`fit_dense`](Self::fit_dense)).
    pub sparsify: SparsifyConfig,
    /// Number of clusters.
    pub k: usize,
    /// Lloyd / restart options.
    pub opts: KmeansOpts,
    /// Fork/join width for assignment + center accumulation. `1` (the
    /// default) runs the serial loops inline; any value yields bitwise
    /// identical fits (see module docs).
    pub workers: usize,
    /// Fork/join width across k-means++ *restarts* (`opts.n_init`). `1`
    /// (the default) runs restarts serially; any value selects the same
    /// best model (see module docs). Only the in-memory
    /// [`fit_chunks`](Self::fit_chunks) path fans restarts out — a
    /// streamed source is a single cursor, so
    /// [`fit_source`](Self::fit_source) restarts serially.
    pub restart_workers: usize,
}

impl SparsifiedKmeans {
    /// Build an Algorithm 1 runner (single-threaded; see
    /// [`with_workers`](Self::with_workers)).
    pub fn new(sparsify: SparsifyConfig, k: usize, opts: KmeansOpts) -> Self {
        SparsifiedKmeans { sparsify, k, opts, workers: 1, restart_workers: 1 }
    }

    /// Builder-style worker-count override (within one restart).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Builder-style restart fan-out override: run `opts.n_init` restarts
    /// on up to `workers` threads, selecting the best inertia exactly as
    /// the serial loop does — deterministic for a fixed seed regardless
    /// of the worker count.
    pub fn with_restart_workers(mut self, workers: usize) -> Self {
        self.restart_workers = workers.max(1);
        self
    }

    /// Convenience: compress a dense matrix (single chunk) and fit.
    pub fn fit_dense(&self, x: &Mat) -> Result<KmeansResult> {
        let sp = Sparsifier::new(x.rows(), self.sparsify)?;
        let chunk = sp.compress_chunk(x, 0)?;
        Ok(self.fit_chunks(&sp, &[chunk], &NativeAssigner::new())?.result)
    }

    /// Fit on already-compressed chunks (the streaming path). `chunks`
    /// must be ordered by `start_col` and contiguous.
    pub fn fit_chunks(
        &self,
        sp: &Sparsifier,
        chunks: &[SparseChunk],
        assigner: &dyn SparseAssigner,
    ) -> Result<SparsifiedModel> {
        self.fit_chunks_raw(sp, chunks, assigner, true)
    }

    /// As [`fit_chunks`](Self::fit_chunks) but with explicit control over
    /// the final center unmixing: pass `unmix = false` when the chunks
    /// were produced *without* preconditioning
    /// ([`Sparsifier::compress_chunk_no_precondition`]) — centers are then
    /// plain masked means and only padding is dropped.
    pub fn fit_chunks_raw(
        &self,
        sp: &Sparsifier,
        chunks: &[SparseChunk],
        assigner: &dyn SparseAssigner,
        unmix: bool,
    ) -> Result<SparsifiedModel> {
        assert!(!chunks.is_empty(), "fit_chunks: no data");
        let n: usize = chunks.iter().map(|c| c.n()).sum();
        let starts = self.opts.n_init.max(1);
        let restart_workers = self.restart_workers.max(1).min(starts);
        if restart_workers <= 1 {
            let mut best: Option<SparsifiedModel> = None;
            for start in 0..starts {
                let mut walk = SliceWalk(chunks);
                let model = self.fit_one_start(sp, n, &mut walk, assigner, unmix, start)?;
                merge_best(&mut best, model);
            }
            return Ok(best.expect("n_init >= 1"));
        }
        // Parallel multi-restart: contiguous blocks of restart indices
        // run on scoped threads, and the remaining thread budget is
        // spent inside each restart (workers / restart blocks), so the
        // total fan-out stays ~self.workers whether restarts or
        // per-restart kernels dominate. Every restart is bitwise
        // deterministic given its sub-RNG stream — the inner width
        // never changes bits — and blocks are merged in start order
        // under the same strictly-better rule as the serial loop, so
        // the selected model is identical for every worker count.
        let inner_workers = (self.workers / restart_workers).max(1);
        let inner = SparsifiedKmeans { workers: inner_workers, restart_workers: 1, ..*self };
        let blocks = parallel::map_ranges(starts, restart_workers, |r| {
            let mut best: Option<SparsifiedModel> = None;
            for start in r {
                let mut walk = SliceWalk(chunks);
                let model = inner.fit_one_start(sp, n, &mut walk, assigner, unmix, start)?;
                merge_best(&mut best, model);
            }
            Ok::<Option<SparsifiedModel>, crate::error::Error>(best)
        });
        let mut best: Option<SparsifiedModel> = None;
        for block in blocks {
            if let Some(model) = block? {
                merge_best(&mut best, model);
            }
        }
        Ok(best.expect("n_init >= 1"))
    }

    /// Fit straight from a rewindable [`SparseChunkSource`] — the
    /// out-of-core path. No stage materializes the sparse matrix: the
    /// k-means++ seeding and every Lloyd iteration are whole passes over
    /// the source (one pass per iteration), so with a memory-budgeted
    /// [`SparseStoreReader`](crate::store::SparseStoreReader) the working
    /// set is the reader budget plus O(p·k·workers) accumulators plus
    /// 12 bytes per sample. Bitwise identical to
    /// [`fit_chunks`](Self::fit_chunks) on the same data for every worker
    /// count, reader memory budget, and chunk granularity.
    ///
    /// Returns the model plus the number of passes *started* over the
    /// sparse source: one per Lloyd iteration plus the seeding's
    /// sub-passes (≈2 per seed — one early-stopped column fetch and one
    /// D² sweep) per restart, and a counting pass when the source gives
    /// no `n_hint`.
    pub fn fit_source(
        &self,
        sp: &Sparsifier,
        source: &mut dyn SparseChunkSource,
        assigner: &dyn SparseAssigner,
        unmix: bool,
    ) -> Result<(SparsifiedModel, usize)> {
        if source.p() != sp.p() || source.m() != sp.m() {
            return invalid(format!(
                "kmeans fit: source is p={} m={}, sparsifier is p={} m={}",
                source.p(),
                source.m(),
                sp.p(),
                sp.m()
            ));
        }
        let hint = source.n_hint();
        let mut walk = SourceWalk::new(source);
        let n = match hint {
            Some(n) => n,
            None => {
                let mut n = 0usize;
                walk.walk(&mut |c| {
                    n += c.n();
                    Ok(true)
                })?;
                n
            }
        };
        if n == 0 {
            return invalid("kmeans fit: source is empty");
        }
        let mut best: Option<SparsifiedModel> = None;
        for start in 0..self.opts.n_init.max(1) {
            let model = self.fit_one_start(sp, n, &mut walk, assigner, unmix, start)?;
            merge_best(&mut best, model);
        }
        Ok((best.expect("n_init >= 1"), walk.passes))
    }

    /// Distributed Lloyd over a sparse store: every pass folds one
    /// [`CenterStep`] **per shard**, captures the per-shard updates in a
    /// [`CenterPartial`](crate::distributed::CenterPartial) per partition
    /// (`partitions` contiguous shard ranges — the N "workers"), and
    /// merges the partials before solving the next centers. Because the
    /// partial keeps per-shard subtotals and
    /// [`finalize`](crate::distributed::CenterPartial::finalize) folds
    /// them in shard-index order, the fit is **bitwise identical for
    /// every partition count and merge order** — `partitions` only
    /// changes how the work would be dealt across workers, never the
    /// model. (It is *not* bitwise identical to
    /// [`fit_source`](Self::fit_source), whose single `CenterStep`
    /// accumulates across shard boundaries — a different f64
    /// association; `partitions = 1` is the distributed reference.)
    ///
    /// Returns the best model plus the number of sparse passes started
    /// (seeding sub-passes + one per Lloyd iteration per restart).
    pub fn fit_store_partitioned(
        &self,
        sp: &Sparsifier,
        reader: &mut crate::store::SparseStoreReader,
        assigner: &dyn SparseAssigner,
        unmix: bool,
        partitions: usize,
    ) -> Result<(SparsifiedModel, usize)> {
        use crate::distributed::{CenterPartial, PartialFit};

        if reader.p() != sp.p() || reader.m() != sp.m() {
            return invalid(format!(
                "kmeans fit: store is p={} m={}, sparsifier is p={} m={}",
                reader.p(),
                reader.m(),
                sp.p(),
                sp.m()
            ));
        }
        let p = sp.p();
        let m = sp.m();
        let manifest = reader.manifest();
        let n = manifest.n;
        let shards: Vec<(usize, usize, usize)> =
            manifest.shards.iter().map(|s| (s.index, s.start_col, s.n_cols)).collect();
        if n == 0 {
            return invalid("kmeans fit: store is empty");
        }
        let ranges = parallel::split_ranges(shards.len(), partitions.max(1));
        let mut passes = 0usize;
        let mut best: Option<SparsifiedModel> = None;
        for start in 0..self.opts.n_init.max(1) {
            let mut rng = Pcg64::seed_stream(self.opts.seed, 0xC0DE ^ start as u64);
            // Algorithm 1 line 5: seeding is a whole-store walk — the
            // same pass for every partition count
            let mut centers = {
                let mut walk = SourceWalk::new(&mut *reader);
                let centers = kmeans_pp_walk(&mut walk, p, n, self.k, &mut rng)?;
                passes += walk.passes;
                centers
            };
            let mut assign = vec![0u32; n];
            let mut have_assign = false;
            let mut obj = f64::INFINITY;
            let mut iterations = 0;
            let mut converged = false;
            let mut center_bound = Vec::new();
            for it in 0..self.opts.max_iters {
                // one pass = one CenterStep per shard, one partial per
                // partition, merged by disjoint union
                let mut merged = CenterPartial::new(p, self.k);
                for range in &ranges {
                    let mut partial = CenterPartial::new(p, self.k);
                    for &(index, start_col, n_cols) in &shards[range.clone()] {
                        let mut step = CenterStep::new(p, self.k, self.workers);
                        step.begin();
                        reader.seek_to_col(start_col)?;
                        let mut covered = 0usize;
                        while covered < n_cols {
                            let Some(chunk) = reader.next_chunk()? else { break };
                            covered += chunk.n();
                            step.fold(&chunk, &centers, assigner)?;
                        }
                        if covered != n_cols {
                            return invalid(format!(
                                "kmeans fit: shard {index} pass covered {covered} of \
                                 {n_cols} columns"
                            ));
                        }
                        partial.insert_step(index as u32, &step)?;
                    }
                    merged.merge_from(&partial)?;
                }
                passes += 1;
                if merged.n() != n {
                    return invalid(format!(
                        "kmeans fit: pass covered {} of {n} samples",
                        merged.n()
                    ));
                }
                let sizes = merged.cluster_sizes();
                let update = merged.finalize(&centers)?;
                let changed = if have_assign {
                    assign.iter().zip(update.assign.iter()).filter(|(a, b)| a != b).count()
                } else {
                    n
                };
                assign.copy_from_slice(&update.assign);
                have_assign = true;
                obj = update.objective;
                center_bound.push(if sp.weighted() {
                    f64::NAN
                } else {
                    sizes
                        .iter()
                        .filter(|&&nk| nk > 0)
                        .map(|&nk| {
                            crate::estimators::center_error_bound(p, m, nk, CENTER_BOUND_DELTA)
                        })
                        .fold(0.0f64, f64::max)
                });
                centers = update.centers;
                iterations = it + 1;
                if (changed as f64) <= self.opts.tol_frac * n as f64 {
                    converged = true;
                    break;
                }
            }
            let centers_orig = if unmix { sp.unmix(&centers) } else { sp.truncate(&centers) };
            merge_best(
                &mut best,
                SparsifiedModel {
                    result: KmeansResult {
                        centers: centers_orig,
                        assign,
                        objective: obj,
                        iterations,
                        converged,
                    },
                    centers_precond: centers,
                    center_bound,
                },
            );
        }
        Ok((best.expect("n_init >= 1"), passes))
    }

    /// One restart: k-means++ seeding then Lloyd iterations, all as
    /// whole-pass folds over `walk` through the [`CenterStep`] kernel.
    fn fit_one_start(
        &self,
        sp: &Sparsifier,
        n: usize,
        walk: &mut dyn ChunkWalk,
        assigner: &dyn SparseAssigner,
        unmix: bool,
        start: usize,
    ) -> Result<SparsifiedModel> {
        let p = sp.p();
        let m = sp.m();
        let mut rng = Pcg64::seed_stream(self.opts.seed, 0xC0DE ^ start as u64);
        // Algorithm 1 line 5: seeding on the sparse matrix
        let mut centers = kmeans_pp_walk(walk, p, n, self.k, &mut rng)?;
        let mut step = CenterStep::new(p, self.k, self.workers);
        let mut assign = vec![0u32; n];
        let mut have_assign = false;
        let mut obj = f64::INFINITY;
        let mut iterations = 0;
        let mut converged = false;
        let mut center_bound = Vec::new();
        for it in 0..self.opts.max_iters {
            // one pass: Step 1 (Eq. 36) + Step 2 (Eq. 39) fused per chunk
            step.begin();
            walk.walk(&mut |chunk| {
                step.fold(chunk, &centers, assigner)?;
                Ok(true)
            })?;
            if step.n() != n {
                return invalid(format!(
                    "kmeans fit: pass covered {} of {n} samples",
                    step.n()
                ));
            }
            let changed = if have_assign {
                assign.iter().zip(step.assign()).filter(|(a, b)| a != b).count()
            } else {
                n
            };
            assign.copy_from_slice(step.assign());
            have_assign = true;
            // the objective is reduced in sample order, so it does not
            // depend on chunking or worker count
            obj = step.objective();
            // the paper's per-step guarantee: worst-cluster Eq. 43 bound
            // at this iteration's observed cluster sizes. The bound's
            // Bernstein constants are derived for the uniform
            // (without-replacement, unweighted) schemes; weighted
            // (hybrid) fits record NaN so the report never presents an
            // invalid number as a guarantee.
            center_bound.push(if sp.weighted() {
                f64::NAN
            } else {
                step.cluster_sizes()
                    .iter()
                    .filter(|&&nk| nk > 0)
                    .map(|&nk| {
                        crate::estimators::center_error_bound(p, m, nk, CENTER_BOUND_DELTA)
                    })
                    .fold(0.0f64, f64::max)
            });
            centers = step.solve(&centers);
            iterations = it + 1;
            if (changed as f64) <= self.opts.tol_frac * n as f64 {
                converged = true;
                break;
            }
        }
        // Eq. 32: unmix to the original domain (or just drop padding
        // for the no-preconditioning ablation)
        let centers_orig = if unmix { sp.unmix(&centers) } else { sp.truncate(&centers) };
        Ok(SparsifiedModel {
            result: KmeansResult {
                centers: centers_orig,
                assign,
                objective: obj,
                iterations,
                converged,
            },
            centers_precond: centers,
            center_bound,
        })
    }
}

/// Best-inertia merge, visiting candidates in restart order: strictly
/// better objectives win, so the earliest of exact ties is kept — the
/// same rule at every fan-out.
fn merge_best(best: &mut Option<SparsifiedModel>, candidate: SparsifiedModel) {
    if best
        .as_ref()
        .map_or(true, |b| candidate.result.objective < b.result.objective)
    {
        *best = Some(candidate);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian_blobs;
    use crate::metrics::clustering_accuracy;
    use crate::sparse::SparseVecSource;
    use crate::transform::TransformKind;

    fn fit(gamma: f64, seed: u64, n: usize) -> (KmeansResult, Vec<u32>) {
        let mut rng = Pcg64::seed(seed);
        let d = gaussian_blobs(64, n, 3, 0.05, &mut rng);
        let cfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed };
        let sk = SparsifiedKmeans::new(cfg, 3, KmeansOpts { n_init: 8, ..Default::default() });
        (sk.fit_dense(&d.data).unwrap(), d.labels)
    }

    #[test]
    fn recovers_well_separated_blobs_at_low_gamma() {
        let (res, labels) = fit(0.15, 11, 600);
        let acc = clustering_accuracy(&res.assign, &labels, 3);
        assert!(acc > 0.9, "accuracy {acc}");
        assert_eq!(res.centers.rows(), 64);
    }

    #[test]
    fn centers_close_to_true_means_one_pass() {
        // the consistency property (Thm 8 / §VII.B): 1-pass centers land
        // near the true cluster means in the ORIGINAL domain
        let mut rng = Pcg64::seed(21);
        let d = gaussian_blobs(64, 3000, 3, 0.05, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.2, transform: TransformKind::Hadamard, seed: 4 };
        let sk = SparsifiedKmeans::new(cfg, 3, KmeansOpts { n_init: 3, ..Default::default() });
        let res = sk.fit_dense(&d.data).unwrap();
        // match each estimated center to nearest true center
        let mut worst = 0.0f64;
        for c in 0..3 {
            let mut best = f64::INFINITY;
            for t in 0..3 {
                let dd: f64 = res
                    .centers
                    .col(c)
                    .iter()
                    .zip(d.centers.col(t))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                best = best.min(dd.sqrt());
            }
            worst = worst.max(best);
        }
        let scale = d.centers.max_col_norm();
        assert!(worst / scale < 0.2, "center error {worst} vs scale {scale}");
    }

    #[test]
    fn chunked_equals_monolithic() {
        let mut rng = Pcg64::seed(31);
        let d = gaussian_blobs(32, 400, 3, 0.1, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 6 };
        let sp = Sparsifier::new(32, cfg).unwrap();
        let opts = KmeansOpts { n_init: 2, ..Default::default() };
        let sk = SparsifiedKmeans::new(cfg, 3, opts);

        let whole = sp.compress_chunk(&d.data, 0).unwrap();
        let mono = sk.fit_chunks(&sp, &[whole], &NativeAssigner::new()).unwrap();

        let c0 = sp.compress_chunk(&d.data.col_range(0, 150), 0).unwrap();
        let c1 = sp.compress_chunk(&d.data.col_range(150, 400), 150).unwrap();
        let split = sk.fit_chunks(&sp, &[c0, c1], &NativeAssigner::new()).unwrap();

        assert_eq!(mono.result.assign, split.result.assign);
        assert!((mono.result.objective - split.result.objective).abs() < 1e-9);
        assert!(
            mono.result.centers.sub(&split.result.centers).max_abs() < 1e-9,
            "centers differ"
        );
    }

    #[test]
    fn workers_do_not_change_the_fit() {
        // the whole point of the output-partitioned parallel layer:
        // workers ∈ {1, 2, 4} must produce identical assignments and
        // bitwise-identical centers/objective
        let mut rng = Pcg64::seed(91);
        // 2500 samples: past the serial-fallback crossover so the assigner
        // genuinely fans out
        let d = gaussian_blobs(64, 2500, 3, 0.1, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.2, transform: TransformKind::Hadamard, seed: 17 };
        let sp = Sparsifier::new(64, cfg).unwrap();
        let c0 = sp.compress_chunk(&d.data.col_range(0, 1100), 0).unwrap();
        let c1 = sp.compress_chunk(&d.data.col_range(1100, 2500), 1100).unwrap();
        let chunks = [c0, c1];
        let opts = KmeansOpts { n_init: 2, ..Default::default() };
        let base = SparsifiedKmeans::new(cfg, 3, opts)
            .fit_chunks(&sp, &chunks, &NativeAssigner::new())
            .unwrap();
        assert_eq!(base.result.assign.len(), 2500);
        for w in [2usize, 4] {
            let par = SparsifiedKmeans::new(cfg, 3, opts)
                .with_workers(w)
                .fit_chunks(&sp, &chunks, &NativeAssigner::new())
                .unwrap();
            assert_eq!(base.result.assign, par.result.assign, "workers={w}");
            assert_eq!(
                base.result.objective.to_bits(),
                par.result.objective.to_bits(),
                "workers={w}"
            );
            assert_eq!(base.result.iterations, par.result.iterations);
            for (a, b) in base
                .centers_precond
                .as_slice()
                .iter()
                .zip(par.centers_precond.as_slice())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "precond centers, workers={w}");
            }
            for (a, b) in
                base.result.centers.as_slice().iter().zip(par.result.centers.as_slice())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "unmixed centers, workers={w}");
            }
        }
    }

    #[test]
    fn parallel_restarts_select_the_same_model() {
        // the --restarts contract: n_init restarts fanned out over any
        // number of threads pick the same best model, bit for bit
        let mut rng = Pcg64::seed(57);
        let d = gaussian_blobs(32, 600, 4, 0.3, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.25, transform: TransformKind::Hadamard, seed: 2 };
        let sp = Sparsifier::new(32, cfg).unwrap();
        let chunks = [sp.compress_chunk(&d.data, 0).unwrap()];
        let opts = KmeansOpts { n_init: 6, ..Default::default() };
        let base = SparsifiedKmeans::new(cfg, 4, opts)
            .fit_chunks(&sp, &chunks, &NativeAssigner::new())
            .unwrap();
        for rw in [2usize, 3, 8] {
            let par = SparsifiedKmeans::new(cfg, 4, opts)
                .with_restart_workers(rw)
                .fit_chunks(&sp, &chunks, &NativeAssigner::new())
                .unwrap();
            assert_eq!(base.result.assign, par.result.assign, "restart workers={rw}");
            assert_eq!(
                base.result.objective.to_bits(),
                par.result.objective.to_bits(),
                "restart workers={rw}"
            );
            assert_eq!(base.result.iterations, par.result.iterations);
            for (a, b) in base
                .centers_precond
                .as_slice()
                .iter()
                .zip(par.centers_precond.as_slice())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "centers, restart workers={rw}");
            }
            assert_eq!(base.center_bound.len(), par.center_bound.len());
            for (a, b) in base.center_bound.iter().zip(&par.center_bound) {
                assert_eq!(a.to_bits(), b.to_bits(), "bounds, restart workers={rw}");
            }
        }
    }

    #[test]
    fn fit_source_matches_fit_chunks_bitwise() {
        // streaming Lloyd over a source == in-memory fit, at several
        // chunk granularities (the store-reader memory-budget shape)
        let mut rng = Pcg64::seed(63);
        let d = gaussian_blobs(32, 500, 3, 0.2, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 9 };
        let sp = Sparsifier::new(32, cfg).unwrap();
        let whole = sp.compress_chunk(&d.data, 0).unwrap();
        let opts = KmeansOpts { n_init: 2, ..Default::default() };
        let sk = SparsifiedKmeans::new(cfg, 3, opts);
        let base = sk.fit_chunks(&sp, &[whole], &NativeAssigner::new()).unwrap();
        for bounds in [vec![0usize, 500], vec![0, 70, 500], vec![0, 1, 250, 499, 500]] {
            let pieces: Vec<SparseChunk> = bounds
                .windows(2)
                .map(|w| sp.compress_chunk(&d.data.col_range(w[0], w[1]), w[0]).unwrap())
                .collect();
            let mut src = SparseVecSource::new(pieces).unwrap();
            let (got, passes) = sk.fit_source(&sp, &mut src, &NativeAssigner::new(), true).unwrap();
            assert!(passes > 0);
            assert_eq!(base.result.assign, got.result.assign, "bounds {bounds:?}");
            assert_eq!(
                base.result.objective.to_bits(),
                got.result.objective.to_bits(),
                "bounds {bounds:?}"
            );
            for (a, b) in base
                .result
                .centers
                .as_slice()
                .iter()
                .zip(got.result.centers.as_slice())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "bounds {bounds:?}");
            }
            for (a, b) in base.center_bound.iter().zip(&got.center_bound) {
                assert_eq!(a.to_bits(), b.to_bits(), "bounds {bounds:?}");
            }
        }
    }

    #[test]
    fn center_bound_tracks_iterations_and_dominates_deviation() {
        let mut rng = Pcg64::seed(71);
        let d = gaussian_blobs(64, 2000, 3, 0.05, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 3 };
        let sp = Sparsifier::new(64, cfg).unwrap();
        let chunks = [sp.compress_chunk(&d.data, 0).unwrap()];
        let opts = KmeansOpts { n_init: 1, ..Default::default() };
        let model = SparsifiedKmeans::new(cfg, 3, opts)
            .fit_chunks(&sp, &chunks, &NativeAssigner::new())
            .unwrap();
        // one bound per Lloyd iteration, all finite and positive
        assert_eq!(model.center_bound.len(), model.result.iterations);
        assert!(model.center_bound.iter().all(|b| b.is_finite() && *b > 0.0));
        // with ~666 members per cluster at gamma=0.3 the guarantee is
        // non-vacuous (well below the trivial ||H_k|| scale p/m)
        let last = *model.center_bound.last().unwrap();
        assert!(last < sp.p() as f64 / sp.m() as f64, "bound {last} is vacuous");
        // and it matches a direct evaluation at the final cluster sizes
        let mut sizes = vec![0usize; 3];
        for &a in &model.result.assign {
            sizes[a as usize] += 1;
        }
        let direct = sizes
            .iter()
            .filter(|&&nk| nk > 0)
            .map(|&nk| crate::estimators::center_error_bound(sp.p(), sp.m(), nk, CENTER_BOUND_DELTA))
            .fold(0.0f64, f64::max);
        assert_eq!(last.to_bits(), direct.to_bits());
    }

    #[test]
    fn assign_into_default_and_parallel_agree() {
        // 4400 samples: enough for a real 4-way fan-out past the
        // serial-fallback crossover gate
        let n = 4400usize;
        let mut rng = Pcg64::seed(53);
        let d = gaussian_blobs(32, n, 3, 0.2, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.25, transform: TransformKind::Hadamard, seed: 9 };
        let sp = Sparsifier::new(32, cfg).unwrap();
        let chunk = sp.compress_chunk(&d.data, 0).unwrap();
        let mut rng2 = Pcg64::seed(54);
        let centers = sp.precondition_dense(&random_column_seed(&chunk, 3, &mut rng2));
        let (ids_ref, obj_ref) = NativeAssigner::new().assign(&chunk, &centers).unwrap();
        for w in [1usize, 4] {
            let mut ids = vec![0u32; n];
            let mut dist = vec![0.0f64; n];
            NativeAssigner::new().assign_into(&chunk, &centers, w, &mut ids, &mut dist).unwrap();
            assert_eq!(ids, ids_ref, "workers={w}");
            let obj: f64 = dist.iter().sum();
            assert_eq!(obj.to_bits(), obj_ref.to_bits(), "workers={w}");
        }
    }

    #[test]
    fn isa_tiers_assign_bitwise_identically() {
        // same chunk/centers through every ISA tier the CPU supports,
        // with k=5 so the panel kernel has a ragged last group (one real
        // lane, three zero dummies): ids and distance bits must match
        // the forced-scalar reference exactly
        let n = 700usize;
        let mut rng = Pcg64::seed(77);
        let d = gaussian_blobs(64, n, 5, 0.3, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.2, transform: TransformKind::Hadamard, seed: 11 };
        let sp = Sparsifier::new(64, cfg).unwrap();
        let chunk = sp.compress_chunk(&d.data, 0).unwrap();
        let mut rng2 = Pcg64::seed(78);
        let centers = sp.precondition_dense(&random_column_seed(&chunk, 5, &mut rng2));
        let scalar = NativeAssigner::new().with_isa(Isa::Scalar);
        let (ids_ref, obj_ref) = scalar.assign(&chunk, &centers).unwrap();
        for isa in [Isa::Sse2, Isa::Avx2] {
            if crate::simd::detect() < isa {
                continue;
            }
            let (ids, obj) =
                NativeAssigner::new().with_isa(isa).assign(&chunk, &centers).unwrap();
            assert_eq!(ids, ids_ref, "{}", isa.name());
            assert_eq!(obj.to_bits(), obj_ref.to_bits(), "{}", isa.name());
        }
    }

    #[test]
    fn cols_per_worker_override_fans_out_bitwise() {
        // n=600 is below every measured crossover, so the default
        // assigner would run serial at workers=4; pinning the threshold
        // to 50 forces a genuine fan-out — which must stay bitwise
        // identical to the serial result
        let n = 600usize;
        let mut rng = Pcg64::seed(91);
        let d = gaussian_blobs(32, n, 3, 0.25, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.25, transform: TransformKind::Hadamard, seed: 5 };
        let sp = Sparsifier::new(32, cfg).unwrap();
        let chunk = sp.compress_chunk(&d.data, 0).unwrap();
        let mut rng2 = Pcg64::seed(92);
        let centers = sp.precondition_dense(&random_column_seed(&chunk, 3, &mut rng2));
        let (ids_ref, obj_ref) = NativeAssigner::new().assign(&chunk, &centers).unwrap();
        let forced = NativeAssigner::new().with_cols_per_worker(50);
        let mut ids = vec![0u32; n];
        let mut dist = vec![0.0f64; n];
        forced.assign_into(&chunk, &centers, 4, &mut ids, &mut dist).unwrap();
        assert_eq!(ids, ids_ref);
        let obj: f64 = dist.iter().sum();
        assert_eq!(obj.to_bits(), obj_ref.to_bits());
    }

    #[test]
    fn assign_cols_override_parsing() {
        assert_eq!(parse_assign_cols_override(None), None);
        assert_eq!(parse_assign_cols_override(Some("512")), Some(512));
        assert_eq!(parse_assign_cols_override(Some("  2048 ")), Some(2048));
        assert_eq!(parse_assign_cols_override(Some("0")), None);
        assert_eq!(parse_assign_cols_override(Some("-4")), None);
        assert_eq!(parse_assign_cols_override(Some("lots")), None);
    }

    #[test]
    fn measured_crossover_table_is_sane() {
        for precision in [Precision::F64, Precision::F32] {
            assert_eq!(measured_cols_per_worker(precision, Isa::Scalar), 1024);
            assert_eq!(measured_cols_per_worker(precision, Isa::Sse2), 1024);
            assert_eq!(measured_cols_per_worker(precision, Isa::Avx2), 2048);
        }
    }

    /// Dense seed helper for the assigner test (original-domain columns).
    fn random_column_seed(chunk: &SparseChunk, k: usize, rng: &mut Pcg64) -> Mat {
        let dense = chunk.to_dense();
        let mut centers = Mat::zeros(dense.rows(), k);
        for c in 0..k {
            let pick = rng.next_range(dense.cols() as u32) as usize;
            centers.col_mut(c).copy_from_slice(dense.col(pick));
        }
        centers
    }

    #[test]
    fn solve_centers_keeps_prev_on_unseen() {
        let sums = Mat::from_vec(2, 1, vec![4.0, 0.0]).unwrap();
        let counts = Mat::from_vec(2, 1, vec![2.0, 0.0]).unwrap();
        let prev = Mat::from_vec(2, 1, vec![9.0, 7.5]).unwrap();
        let out = solve_centers(&sums, &counts, &prev);
        assert_eq!(out.get(0, 0), 2.0);
        assert_eq!(out.get(1, 0), 7.5);
    }

    #[test]
    fn higher_gamma_does_not_hurt_much() {
        let (lo, labels_lo) = fit(0.05, 51, 900);
        let (hi, labels_hi) = fit(0.5, 51, 900);
        let acc_lo = clustering_accuracy(&lo.assign, &labels_lo, 3);
        let acc_hi = clustering_accuracy(&hi.assign, &labels_hi, 3);
        assert!(acc_hi >= acc_lo - 0.05, "γ=0.5 acc {acc_hi} vs γ=0.05 acc {acc_lo}");
    }
}
