//! Sparsified K-means — paper Algorithm 1.
//!
//! Operates entirely on [`SparseChunk`]s (preconditioned + sampled data):
//! k-means++ seeding on the sparse matrix, masked-distance assignments
//! (Eq. 36), entry-wise masked center averaging (Eq. 39), and a final
//! unmix `μ = (HD)ᵀ μ'` (Eq. 32). One pass over the data produces both
//! assignments *and* original-domain centers — the paper's headline
//! property.

use crate::error::Result;
use crate::linalg::Mat;
use crate::rng::Pcg64;
use crate::sampling::{Sparsifier, SparsifyConfig};
use crate::sparse::SparseChunk;

use super::plusplus::{kmeans_pp_sparse, masked_dist2};
use super::{KmeansOpts, KmeansResult};

/// Strategy for the per-chunk assignment step — the pipeline hot spot.
/// Implemented natively ([`sparsified`](self)) and by the PJRT runtime
/// (`runtime::XlaEngine`) executing the AOT Pallas `assign` graph.
pub trait SparseAssigner {
    /// Assign each column of `chunk` to its nearest center (centers live
    /// in the preconditioned domain, `p × K`). Returns per-column cluster
    /// ids and the summed min masked distance (the Eq. 34 objective).
    fn assign(&self, chunk: &SparseChunk, centers: &Mat) -> Result<(Vec<u32>, f64)>;

    /// Human-readable engine name (for experiment tables).
    fn name(&self) -> &'static str {
        "native"
    }
}

/// Pure-Rust masked-distance assigner. Uses the same algebraic expansion
/// as the Pallas kernel — `‖w‖² − 2⟨w,μ⟩ + Σ_mask μ²` — but traverses the
/// m kept indices per sample instead of masking dense panels (optimal on
/// CPU where gathers are cheap and FLOPs are not).
pub struct NativeAssigner;

impl SparseAssigner for NativeAssigner {
    fn assign(&self, chunk: &SparseChunk, centers: &Mat) -> Result<(Vec<u32>, f64)> {
        // Perf note (§Perf log): a K-simultaneous accumulator over a
        // transposed center panel was tried and measured 2x SLOWER than
        // this center-major form — the single-accumulator inner loop
        // vectorizes, the K-wide one does not. Keep center-major.
        let k = centers.cols();
        let mut assign = vec![0u32; chunk.n()];
        let mut obj = 0.0;
        for i in 0..chunk.n() {
            let idx = chunk.col_indices(i);
            let vals = chunk.col_values(i);
            let mut best = f64::INFINITY;
            let mut arg = 0u32;
            for c in 0..k {
                let d = masked_dist2(idx, vals, centers.col(c));
                if d < best {
                    best = d;
                    arg = c as u32;
                }
            }
            assign[i] = arg;
            obj += best;
        }
        Ok((assign, obj))
    }
}

/// Accumulate one chunk's contribution to the masked center update
/// (Eq. 39): `sums[j,k] += w_ij`, `counts[j,k] += 1` over kept entries of
/// samples assigned to `k`.
pub fn accumulate_center_update(
    chunk: &SparseChunk,
    assign: &[u32],
    sums: &mut Mat,
    counts: &mut Mat,
) {
    debug_assert_eq!(assign.len(), chunk.n());
    for i in 0..chunk.n() {
        let c = assign[i] as usize;
        let scol = sums.col_mut(c);
        for (&j, &v) in chunk.col_indices(i).iter().zip(chunk.col_values(i)) {
            scol[j as usize] += v;
        }
        let ccol = counts.col_mut(c);
        for &j in chunk.col_indices(i) {
            ccol[j as usize] += 1.0;
        }
    }
}

/// Solve the diagonal system of Eq. (39)/(40): `μ'_jk = sums/counts` where
/// observed; coordinates never sampled within a cluster keep `prev`'s
/// entry (the paper removes them from the system — equivalent to not
/// moving that coordinate).
pub fn solve_centers(sums: &Mat, counts: &Mat, prev: &Mat) -> Mat {
    let (p, k) = (sums.rows(), sums.cols());
    let mut out = Mat::zeros(p, k);
    for c in 0..k {
        let (s, cnt, pv, dst) = (sums.col(c), counts.col(c), prev.col(c), out.col_mut(c));
        for j in 0..p {
            dst[j] = if cnt[j] > 0.0 { s[j] / cnt[j] } else { pv[j] };
        }
    }
    out
}

/// The fitted sparsified model: result plus the preconditioned-domain
/// centers (useful for resuming / streaming assignment of new data).
pub struct SparsifiedModel {
    pub result: KmeansResult,
    /// Centers in the preconditioned (padded) domain, p_work × K.
    pub centers_precond: Mat,
}

/// Sparsified K-means (Algorithm 1).
pub struct SparsifiedKmeans {
    pub sparsify: SparsifyConfig,
    pub k: usize,
    pub opts: KmeansOpts,
}

impl SparsifiedKmeans {
    pub fn new(sparsify: SparsifyConfig, k: usize, opts: KmeansOpts) -> Self {
        SparsifiedKmeans { sparsify, k, opts }
    }

    /// Convenience: compress a dense matrix (single chunk) and fit.
    pub fn fit_dense(&self, x: &Mat) -> Result<KmeansResult> {
        let sp = Sparsifier::new(x.rows(), self.sparsify)?;
        let chunk = sp.compress_chunk(x, 0)?;
        Ok(self.fit_chunks(&sp, &[chunk], &NativeAssigner)?.result)
    }

    /// Fit on already-compressed chunks (the streaming path). `chunks`
    /// must be ordered by `start_col` and contiguous.
    pub fn fit_chunks(
        &self,
        sp: &Sparsifier,
        chunks: &[SparseChunk],
        assigner: &dyn SparseAssigner,
    ) -> Result<SparsifiedModel> {
        self.fit_chunks_raw(sp, chunks, assigner, true)
    }

    /// As [`fit_chunks`](Self::fit_chunks) but with explicit control over
    /// the final center unmixing: pass `unmix = false` when the chunks
    /// were produced *without* preconditioning
    /// ([`Sparsifier::compress_chunk_no_precondition`]) — centers are then
    /// plain masked means and only padding is dropped.
    pub fn fit_chunks_raw(
        &self,
        sp: &Sparsifier,
        chunks: &[SparseChunk],
        assigner: &dyn SparseAssigner,
        unmix: bool,
    ) -> Result<SparsifiedModel> {
        assert!(!chunks.is_empty(), "fit_chunks: no data");
        let p = sp.p();
        let n: usize = chunks.iter().map(|c| c.n()).sum();
        let mut best: Option<SparsifiedModel> = None;
        for start in 0..self.opts.n_init.max(1) {
            let mut rng = Pcg64::seed_stream(self.opts.seed, 0xC0DE ^ start as u64);
            let mut centers = kmeans_pp_sparse(chunks, self.k, &mut rng);
            let mut assign = vec![0u32; n];
            let mut have_assign = false;
            let mut obj = f64::INFINITY;
            let mut iterations = 0;
            let mut converged = false;
            for it in 0..self.opts.max_iters {
                // Step 1 (Eq. 36): assignments
                let mut changed = 0usize;
                let mut new_obj = 0.0;
                let mut sums = Mat::zeros(p, self.k);
                let mut counts = Mat::zeros(p, self.k);
                let mut off = 0usize;
                for chunk in chunks {
                    let (a, o) = assigner.assign(chunk, &centers)?;
                    new_obj += o;
                    for (i, &c) in a.iter().enumerate() {
                        if !have_assign || assign[off + i] != c {
                            changed += 1;
                        }
                        assign[off + i] = c;
                    }
                    // Step 2 (Eq. 39): accumulate masked sums/counts
                    accumulate_center_update(chunk, &a, &mut sums, &mut counts);
                    off += chunk.n();
                }
                have_assign = true;
                obj = new_obj;
                centers = solve_centers(&sums, &counts, &centers);
                iterations = it + 1;
                if (changed as f64) <= self.opts.tol_frac * n as f64 {
                    converged = true;
                    break;
                }
            }
            // Eq. 32: unmix to the original domain (or just drop padding
            // for the no-preconditioning ablation)
            let centers_orig =
                if unmix { sp.unmix(&centers) } else { sp.truncate(&centers) };
            let candidate = SparsifiedModel {
                result: KmeansResult {
                    centers: centers_orig,
                    assign: assign.clone(),
                    objective: obj,
                    iterations,
                    converged,
                },
                centers_precond: centers,
            };
            if best.as_ref().map_or(true, |b| candidate.result.objective < b.result.objective) {
                best = Some(candidate);
            }
        }
        Ok(best.expect("n_init >= 1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian_blobs;
    use crate::metrics::clustering_accuracy;
    use crate::transform::TransformKind;

    fn fit(gamma: f64, seed: u64, n: usize) -> (KmeansResult, Vec<u32>) {
        let mut rng = Pcg64::seed(seed);
        let d = gaussian_blobs(64, n, 3, 0.05, &mut rng);
        let cfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed };
        let sk = SparsifiedKmeans::new(cfg, 3, KmeansOpts { n_init: 8, ..Default::default() });
        (sk.fit_dense(&d.data).unwrap(), d.labels)
    }

    #[test]
    fn recovers_well_separated_blobs_at_low_gamma() {
        let (res, labels) = fit(0.15, 11, 600);
        let acc = clustering_accuracy(&res.assign, &labels, 3);
        assert!(acc > 0.9, "accuracy {acc}");
        assert_eq!(res.centers.rows(), 64);
    }

    #[test]
    fn centers_close_to_true_means_one_pass() {
        // the consistency property (Thm 8 / §VII.B): 1-pass centers land
        // near the true cluster means in the ORIGINAL domain
        let mut rng = Pcg64::seed(21);
        let d = gaussian_blobs(64, 3000, 3, 0.05, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.2, transform: TransformKind::Hadamard, seed: 4 };
        let sk = SparsifiedKmeans::new(cfg, 3, KmeansOpts { n_init: 3, ..Default::default() });
        let res = sk.fit_dense(&d.data).unwrap();
        // match each estimated center to nearest true center
        let mut worst = 0.0f64;
        for c in 0..3 {
            let mut best = f64::INFINITY;
            for t in 0..3 {
                let dd: f64 = res
                    .centers
                    .col(c)
                    .iter()
                    .zip(d.centers.col(t))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                best = best.min(dd.sqrt());
            }
            worst = worst.max(best);
        }
        let scale = d.centers.max_col_norm();
        assert!(worst / scale < 0.2, "center error {worst} vs scale {scale}");
    }

    #[test]
    fn chunked_equals_monolithic() {
        let mut rng = Pcg64::seed(31);
        let d = gaussian_blobs(32, 400, 3, 0.1, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 6 };
        let sp = Sparsifier::new(32, cfg).unwrap();
        let opts = KmeansOpts { n_init: 2, ..Default::default() };
        let sk = SparsifiedKmeans::new(cfg, 3, opts);

        let whole = sp.compress_chunk(&d.data, 0).unwrap();
        let mono = sk.fit_chunks(&sp, &[whole], &NativeAssigner).unwrap();

        let c0 = sp.compress_chunk(&d.data.col_range(0, 150), 0).unwrap();
        let c1 = sp.compress_chunk(&d.data.col_range(150, 400), 150).unwrap();
        let split = sk.fit_chunks(&sp, &[c0, c1], &NativeAssigner).unwrap();

        assert_eq!(mono.result.assign, split.result.assign);
        assert!((mono.result.objective - split.result.objective).abs() < 1e-9);
        assert!(
            mono.result.centers.sub(&split.result.centers).max_abs() < 1e-9,
            "centers differ"
        );
    }

    #[test]
    fn solve_centers_keeps_prev_on_unseen() {
        let sums = Mat::from_vec(2, 1, vec![4.0, 0.0]).unwrap();
        let counts = Mat::from_vec(2, 1, vec![2.0, 0.0]).unwrap();
        let prev = Mat::from_vec(2, 1, vec![9.0, 7.5]).unwrap();
        let out = solve_centers(&sums, &counts, &prev);
        assert_eq!(out.get(0, 0), 2.0);
        assert_eq!(out.get(1, 0), 7.5);
    }

    #[test]
    fn higher_gamma_does_not_hurt_much() {
        let (lo, labels_lo) = fit(0.05, 51, 900);
        let (hi, labels_hi) = fit(0.5, 51, 900);
        let acc_lo = clustering_accuracy(&lo.assign, &labels_lo, 3);
        let acc_hi = clustering_accuracy(&hi.assign, &labels_hi, 3);
        assert!(acc_hi >= acc_lo - 0.05, "γ=0.5 acc {acc_hi} vs γ=0.05 acc {acc_lo}");
    }
}
