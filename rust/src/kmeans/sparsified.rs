//! Sparsified K-means — paper Algorithm 1.
//!
//! Operates entirely on [`SparseChunk`]s (preconditioned + sampled data):
//! k-means++ seeding on the sparse matrix, masked-distance assignments
//! (Eq. 36), entry-wise masked center averaging (Eq. 39), and a final
//! unmix `μ = (HD)ᵀ μ'` (Eq. 32). One pass over the data produces both
//! assignments *and* original-domain centers — the paper's headline
//! property.
//!
//! Both hot steps fan out over [`crate::parallel`] scoped threads when
//! `workers > 1`: assignment partitions the *samples* (embarrassingly
//! parallel; per-sample distances are recorded and reduced in sample
//! order), the center update partitions the *coordinates* (each worker
//! owns a row range of `sums`/`counts`, so every cell is accumulated by
//! exactly one worker in global sample order). Results are therefore
//! bitwise identical for every worker count, including `workers = 1` —
//! which runs the original serial loops inline.

use std::ops::Range;

use crate::error::Result;
use crate::linalg::Mat;
use crate::parallel;
use crate::rng::Pcg64;
use crate::sampling::{Sparsifier, SparsifyConfig};
use crate::sparse::SparseChunk;

use super::plusplus::{kmeans_pp_sparse, masked_dist2};
use super::{KmeansOpts, KmeansResult};

/// Strategy for the per-chunk assignment step — the pipeline hot spot.
/// Implemented natively ([`sparsified`](self)) and by the PJRT runtime
/// (`runtime::XlaEngine`) executing the AOT Pallas `assign` graph.
pub trait SparseAssigner {
    /// Assign each column of `chunk` to its nearest center (centers live
    /// in the preconditioned domain, `p × K`). Returns per-column cluster
    /// ids and the summed min masked distance (the Eq. 34 objective).
    fn assign(&self, chunk: &SparseChunk, centers: &Mat) -> Result<(Vec<u32>, f64)>;

    /// Assign each column of `chunk`, writing cluster ids into `out` and
    /// each column's min masked distance into `dist` (both of length
    /// `chunk.n()`). `workers` is a parallelism hint an implementation
    /// may ignore. The default forwards to [`assign`](Self::assign) and
    /// recomputes the per-column distances serially.
    fn assign_into(
        &self,
        chunk: &SparseChunk,
        centers: &Mat,
        workers: usize,
        out: &mut [u32],
        dist: &mut [f64],
    ) -> Result<()> {
        let _ = workers;
        let (ids, _obj) = self.assign(chunk, centers)?;
        debug_assert_eq!(ids.len(), chunk.n());
        for i in 0..chunk.n() {
            out[i] = ids[i];
            dist[i] = masked_dist2(
                chunk.col_indices(i),
                chunk.col_values(i),
                centers.col(ids[i] as usize),
            );
        }
        Ok(())
    }

    /// Human-readable engine name (for experiment tables).
    fn name(&self) -> &'static str {
        "native"
    }
}

/// Minimum columns per worker before the parallel assigner fans out.
const MIN_ASSIGN_COLS_PER_WORKER: usize = 1024;

/// Assignment kernel over one contiguous column range.
fn assign_range(
    chunk: &SparseChunk,
    centers: &Mat,
    r: Range<usize>,
    out: &mut [u32],
    dist: &mut [f64],
) {
    let k = centers.cols();
    for (local, i) in r.enumerate() {
        let idx = chunk.col_indices(i);
        let vals = chunk.col_values(i);
        let mut best = f64::INFINITY;
        let mut arg = 0u32;
        for c in 0..k {
            let d = masked_dist2(idx, vals, centers.col(c));
            if d < best {
                best = d;
                arg = c as u32;
            }
        }
        out[local] = arg;
        dist[local] = best;
    }
}

/// Pure-Rust masked-distance assigner. Uses the same algebraic expansion
/// as the Pallas kernel — `‖w‖² − 2⟨w,μ⟩ + Σ_mask μ²` — but traverses the
/// m kept indices per sample instead of masking dense panels (optimal on
/// CPU where gathers are cheap and FLOPs are not).
pub struct NativeAssigner;

impl SparseAssigner for NativeAssigner {
    fn assign(&self, chunk: &SparseChunk, centers: &Mat) -> Result<(Vec<u32>, f64)> {
        // Perf note (§Perf log): a K-simultaneous accumulator over a
        // transposed center panel was tried and measured 2x SLOWER than
        // this center-major form — the single-accumulator inner loop
        // vectorizes, the K-wide one does not. Keep center-major.
        let n = chunk.n();
        let mut assign = vec![0u32; n];
        let mut dist = vec![0.0f64; n];
        assign_range(chunk, centers, 0..n, &mut assign, &mut dist);
        let obj = dist.iter().sum();
        Ok((assign, obj))
    }

    /// Sample-partitioned parallel assignment: each worker owns a
    /// contiguous column range and its matching output slices, so every
    /// per-sample result is computed exactly once by the same kernel as
    /// the serial path — bitwise identical for every worker count.
    fn assign_into(
        &self,
        chunk: &SparseChunk,
        centers: &Mat,
        workers: usize,
        out: &mut [u32],
        dist: &mut [f64],
    ) -> Result<()> {
        let n = chunk.n();
        debug_assert_eq!(out.len(), n);
        debug_assert_eq!(dist.len(), n);
        // below ~1k columns per worker the scoped-thread spawn overhead
        // beats the gather work — fall back to fewer (or zero) forks;
        // the result is bitwise identical either way
        let eff_workers = workers.min(n / MIN_ASSIGN_COLS_PER_WORKER).max(1);
        let ranges = parallel::split_ranges(n, eff_workers);
        if ranges.len() <= 1 {
            assign_range(chunk, centers, 0..n, out, dist);
            return Ok(());
        }
        // carve the output buffers into per-range slices
        let mut jobs: Vec<(Range<usize>, &mut [u32], &mut [f64])> =
            Vec::with_capacity(ranges.len());
        let (mut rest_out, mut rest_dist) = (out, dist);
        for r in ranges {
            let len = r.len();
            let (o, ro) = std::mem::take(&mut rest_out).split_at_mut(len);
            let (d, rd) = std::mem::take(&mut rest_dist).split_at_mut(len);
            rest_out = ro;
            rest_dist = rd;
            jobs.push((r, o, d));
        }
        crossbeam_utils::thread::scope(|scope| {
            let mut iter = jobs.into_iter();
            let first = iter.next().expect("len > 1");
            let handles: Vec<_> = iter
                .map(|(r, o, d)| scope.spawn(move |_| assign_range(chunk, centers, r, o, d)))
                .collect();
            let (r, o, d) = first;
            assign_range(chunk, centers, r, o, d);
            for h in handles {
                h.join().expect("assign worker panicked");
            }
        })
        .expect("assign scope panicked");
        Ok(())
    }
}

/// Accumulate one chunk's contribution to the masked center update
/// (Eq. 39): `sums[j,k] += w_ij`, `counts[j,k] += 1` over kept entries of
/// samples assigned to `k` — one fused pass over each column's indices.
pub fn accumulate_center_update(
    chunk: &SparseChunk,
    assign: &[u32],
    sums: &mut Mat,
    counts: &mut Mat,
) {
    debug_assert_eq!(assign.len(), chunk.n());
    for i in 0..chunk.n() {
        let c = assign[i] as usize;
        let scol = sums.col_mut(c);
        let ccol = counts.col_mut(c);
        for (&j, &v) in chunk.col_indices(i).iter().zip(chunk.col_values(i)) {
            scol[j as usize] += v;
            ccol[j as usize] += 1.0;
        }
    }
}

/// Whole-pass center update over `chunks` (global chunk-ordered `assign`),
/// fanned out over disjoint coordinate ranges. `sums`/`counts` must be
/// zeroed on entry. Each worker owns rows `[lo, hi)` of both matrices and
/// walks all samples in global order, locating its slice of each sorted
/// index column by binary search — so every cell receives its
/// contributions in exactly the serial order regardless of `workers`,
/// making the result bitwise worker-count-invariant.
fn accumulate_center_update_rows(
    chunks: &[SparseChunk],
    assign: &[u32],
    sums: &mut Mat,
    counts: &mut Mat,
    workers: usize,
) {
    let p = sums.rows();
    let k = sums.cols();
    let ranges = parallel::split_ranges(p, workers);
    if ranges.len() <= 1 {
        let mut off = 0usize;
        for chunk in chunks {
            accumulate_center_update(chunk, &assign[off..off + chunk.n()], sums, counts);
            off += chunk.n();
        }
        return;
    }
    let partials = parallel::run_ranges(ranges, |r| {
        let rows = r.len();
        let (lo, hi) = (r.start as u32, r.end as u32);
        let mut s = vec![0.0f64; rows * k];
        let mut cnt = vec![0.0f64; rows * k];
        let mut off = 0usize;
        for chunk in chunks {
            for i in 0..chunk.n() {
                let c = assign[off + i] as usize;
                let idx = chunk.col_indices(i);
                let vals = chunk.col_values(i);
                let a_lo = idx.partition_point(|&j| j < lo);
                let a_hi = a_lo + idx[a_lo..].partition_point(|&j| j < hi);
                let scol = &mut s[c * rows..(c + 1) * rows];
                let ccol = &mut cnt[c * rows..(c + 1) * rows];
                for a in a_lo..a_hi {
                    let j = (idx[a] - lo) as usize;
                    scol[j] += vals[a];
                    ccol[j] += 1.0;
                }
            }
            off += chunk.n();
        }
        (r, s, cnt)
    });
    for (r, s, cnt) in partials {
        let rows = r.len();
        for c in 0..k {
            sums.col_mut(c)[r.start..r.end].copy_from_slice(&s[c * rows..(c + 1) * rows]);
            counts.col_mut(c)[r.start..r.end].copy_from_slice(&cnt[c * rows..(c + 1) * rows]);
        }
    }
}

/// Solve the diagonal system of Eq. (39)/(40): `μ'_jk = sums/counts` where
/// observed; coordinates never sampled within a cluster keep `prev`'s
/// entry (the paper removes them from the system — equivalent to not
/// moving that coordinate).
pub fn solve_centers(sums: &Mat, counts: &Mat, prev: &Mat) -> Mat {
    let (p, k) = (sums.rows(), sums.cols());
    let mut out = Mat::zeros(p, k);
    for c in 0..k {
        let (s, cnt, pv, dst) = (sums.col(c), counts.col(c), prev.col(c), out.col_mut(c));
        for j in 0..p {
            dst[j] = if cnt[j] > 0.0 { s[j] / cnt[j] } else { pv[j] };
        }
    }
    out
}

/// The fitted sparsified model: result plus the preconditioned-domain
/// centers (useful for resuming / streaming assignment of new data).
pub struct SparsifiedModel {
    /// The fitted clustering (centers in the original domain).
    pub result: KmeansResult,
    /// Centers in the preconditioned (padded) domain, p_work × K.
    pub centers_precond: Mat,
}

/// Sparsified K-means (Algorithm 1).
pub struct SparsifiedKmeans {
    /// Compression configuration (used by [`fit_dense`](Self::fit_dense)).
    pub sparsify: SparsifyConfig,
    /// Number of clusters.
    pub k: usize,
    /// Lloyd / restart options.
    pub opts: KmeansOpts,
    /// Fork/join width for assignment + center accumulation. `1` (the
    /// default) runs the serial loops inline; any value yields bitwise
    /// identical fits (see module docs).
    pub workers: usize,
}

impl SparsifiedKmeans {
    /// Build an Algorithm 1 runner (single-threaded; see
    /// [`with_workers`](Self::with_workers)).
    pub fn new(sparsify: SparsifyConfig, k: usize, opts: KmeansOpts) -> Self {
        SparsifiedKmeans { sparsify, k, opts, workers: 1 }
    }

    /// Builder-style worker-count override.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Convenience: compress a dense matrix (single chunk) and fit.
    pub fn fit_dense(&self, x: &Mat) -> Result<KmeansResult> {
        let sp = Sparsifier::new(x.rows(), self.sparsify)?;
        let chunk = sp.compress_chunk(x, 0)?;
        Ok(self.fit_chunks(&sp, &[chunk], &NativeAssigner)?.result)
    }

    /// Fit on already-compressed chunks (the streaming path). `chunks`
    /// must be ordered by `start_col` and contiguous.
    pub fn fit_chunks(
        &self,
        sp: &Sparsifier,
        chunks: &[SparseChunk],
        assigner: &dyn SparseAssigner,
    ) -> Result<SparsifiedModel> {
        self.fit_chunks_raw(sp, chunks, assigner, true)
    }

    /// As [`fit_chunks`](Self::fit_chunks) but with explicit control over
    /// the final center unmixing: pass `unmix = false` when the chunks
    /// were produced *without* preconditioning
    /// ([`Sparsifier::compress_chunk_no_precondition`]) — centers are then
    /// plain masked means and only padding is dropped.
    pub fn fit_chunks_raw(
        &self,
        sp: &Sparsifier,
        chunks: &[SparseChunk],
        assigner: &dyn SparseAssigner,
        unmix: bool,
    ) -> Result<SparsifiedModel> {
        assert!(!chunks.is_empty(), "fit_chunks: no data");
        let p = sp.p();
        let n: usize = chunks.iter().map(|c| c.n()).sum();
        let mut best: Option<SparsifiedModel> = None;
        for start in 0..self.opts.n_init.max(1) {
            let mut rng = Pcg64::seed_stream(self.opts.seed, 0xC0DE ^ start as u64);
            let mut centers = kmeans_pp_sparse(chunks, self.k, &mut rng);
            let mut assign = vec![0u32; n];
            let mut next = vec![0u32; n];
            let mut dist = vec![0.0f64; n];
            let mut have_assign = false;
            let mut obj = f64::INFINITY;
            let mut iterations = 0;
            let mut converged = false;
            for it in 0..self.opts.max_iters {
                // Step 1 (Eq. 36): assignments + per-sample distances
                let mut off = 0usize;
                for chunk in chunks {
                    let cn = chunk.n();
                    assigner.assign_into(
                        chunk,
                        &centers,
                        self.workers,
                        &mut next[off..off + cn],
                        &mut dist[off..off + cn],
                    )?;
                    off += cn;
                }
                let changed = if have_assign {
                    assign.iter().zip(&next).filter(|(a, b)| a != b).count()
                } else {
                    n
                };
                std::mem::swap(&mut assign, &mut next);
                have_assign = true;
                // the objective is reduced in sample order, so it does
                // not depend on chunking or worker count
                obj = dist.iter().sum();
                // Step 2 (Eq. 39): masked sums/counts, then center solve
                let mut sums = Mat::zeros(p, self.k);
                let mut counts = Mat::zeros(p, self.k);
                accumulate_center_update_rows(
                    chunks,
                    &assign,
                    &mut sums,
                    &mut counts,
                    self.workers,
                );
                centers = solve_centers(&sums, &counts, &centers);
                iterations = it + 1;
                if (changed as f64) <= self.opts.tol_frac * n as f64 {
                    converged = true;
                    break;
                }
            }
            // Eq. 32: unmix to the original domain (or just drop padding
            // for the no-preconditioning ablation)
            let centers_orig =
                if unmix { sp.unmix(&centers) } else { sp.truncate(&centers) };
            let candidate = SparsifiedModel {
                result: KmeansResult {
                    centers: centers_orig,
                    assign: assign.clone(),
                    objective: obj,
                    iterations,
                    converged,
                },
                centers_precond: centers,
            };
            if best.as_ref().map_or(true, |b| candidate.result.objective < b.result.objective) {
                best = Some(candidate);
            }
        }
        Ok(best.expect("n_init >= 1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian_blobs;
    use crate::metrics::clustering_accuracy;
    use crate::transform::TransformKind;

    fn fit(gamma: f64, seed: u64, n: usize) -> (KmeansResult, Vec<u32>) {
        let mut rng = Pcg64::seed(seed);
        let d = gaussian_blobs(64, n, 3, 0.05, &mut rng);
        let cfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed };
        let sk = SparsifiedKmeans::new(cfg, 3, KmeansOpts { n_init: 8, ..Default::default() });
        (sk.fit_dense(&d.data).unwrap(), d.labels)
    }

    #[test]
    fn recovers_well_separated_blobs_at_low_gamma() {
        let (res, labels) = fit(0.15, 11, 600);
        let acc = clustering_accuracy(&res.assign, &labels, 3);
        assert!(acc > 0.9, "accuracy {acc}");
        assert_eq!(res.centers.rows(), 64);
    }

    #[test]
    fn centers_close_to_true_means_one_pass() {
        // the consistency property (Thm 8 / §VII.B): 1-pass centers land
        // near the true cluster means in the ORIGINAL domain
        let mut rng = Pcg64::seed(21);
        let d = gaussian_blobs(64, 3000, 3, 0.05, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.2, transform: TransformKind::Hadamard, seed: 4 };
        let sk = SparsifiedKmeans::new(cfg, 3, KmeansOpts { n_init: 3, ..Default::default() });
        let res = sk.fit_dense(&d.data).unwrap();
        // match each estimated center to nearest true center
        let mut worst = 0.0f64;
        for c in 0..3 {
            let mut best = f64::INFINITY;
            for t in 0..3 {
                let dd: f64 = res
                    .centers
                    .col(c)
                    .iter()
                    .zip(d.centers.col(t))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                best = best.min(dd.sqrt());
            }
            worst = worst.max(best);
        }
        let scale = d.centers.max_col_norm();
        assert!(worst / scale < 0.2, "center error {worst} vs scale {scale}");
    }

    #[test]
    fn chunked_equals_monolithic() {
        let mut rng = Pcg64::seed(31);
        let d = gaussian_blobs(32, 400, 3, 0.1, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 6 };
        let sp = Sparsifier::new(32, cfg).unwrap();
        let opts = KmeansOpts { n_init: 2, ..Default::default() };
        let sk = SparsifiedKmeans::new(cfg, 3, opts);

        let whole = sp.compress_chunk(&d.data, 0).unwrap();
        let mono = sk.fit_chunks(&sp, &[whole], &NativeAssigner).unwrap();

        let c0 = sp.compress_chunk(&d.data.col_range(0, 150), 0).unwrap();
        let c1 = sp.compress_chunk(&d.data.col_range(150, 400), 150).unwrap();
        let split = sk.fit_chunks(&sp, &[c0, c1], &NativeAssigner).unwrap();

        assert_eq!(mono.result.assign, split.result.assign);
        assert!((mono.result.objective - split.result.objective).abs() < 1e-9);
        assert!(
            mono.result.centers.sub(&split.result.centers).max_abs() < 1e-9,
            "centers differ"
        );
    }

    #[test]
    fn workers_do_not_change_the_fit() {
        // the whole point of the output-partitioned parallel layer:
        // workers ∈ {1, 2, 4} must produce identical assignments and
        // bitwise-identical centers/objective
        let mut rng = Pcg64::seed(91);
        // 2500 samples: past MIN_ASSIGN_COLS_PER_WORKER so the assigner
        // genuinely fans out
        let d = gaussian_blobs(64, 2500, 3, 0.1, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.2, transform: TransformKind::Hadamard, seed: 17 };
        let sp = Sparsifier::new(64, cfg).unwrap();
        let c0 = sp.compress_chunk(&d.data.col_range(0, 1100), 0).unwrap();
        let c1 = sp.compress_chunk(&d.data.col_range(1100, 2500), 1100).unwrap();
        let chunks = [c0, c1];
        let opts = KmeansOpts { n_init: 2, ..Default::default() };
        let base = SparsifiedKmeans::new(cfg, 3, opts)
            .fit_chunks(&sp, &chunks, &NativeAssigner)
            .unwrap();
        assert_eq!(base.result.assign.len(), 2500);
        for w in [2usize, 4] {
            let par = SparsifiedKmeans::new(cfg, 3, opts)
                .with_workers(w)
                .fit_chunks(&sp, &chunks, &NativeAssigner)
                .unwrap();
            assert_eq!(base.result.assign, par.result.assign, "workers={w}");
            assert_eq!(
                base.result.objective.to_bits(),
                par.result.objective.to_bits(),
                "workers={w}"
            );
            assert_eq!(base.result.iterations, par.result.iterations);
            for (a, b) in base
                .centers_precond
                .as_slice()
                .iter()
                .zip(par.centers_precond.as_slice())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "precond centers, workers={w}");
            }
            for (a, b) in
                base.result.centers.as_slice().iter().zip(par.result.centers.as_slice())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "unmixed centers, workers={w}");
            }
        }
    }

    #[test]
    fn parallel_center_accumulation_matches_serial() {
        // accumulate_center_update_rows at workers > 1 against the fused
        // serial kernel, directly
        let mut rng = Pcg64::seed(47);
        let d = gaussian_blobs(96, 300, 4, 0.2, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.15, transform: TransformKind::Hadamard, seed: 5 };
        let sp = Sparsifier::new(96, cfg).unwrap();
        let c0 = sp.compress_chunk(&d.data.col_range(0, 130), 0).unwrap();
        let c1 = sp.compress_chunk(&d.data.col_range(130, 300), 130).unwrap();
        let chunks = [c0, c1];
        let assign: Vec<u32> = (0..300).map(|i| (i % 4) as u32).collect();
        let p = sp.p();
        let mut s_ser = Mat::zeros(p, 4);
        let mut c_ser = Mat::zeros(p, 4);
        accumulate_center_update(&chunks[0], &assign[..130], &mut s_ser, &mut c_ser);
        accumulate_center_update(&chunks[1], &assign[130..], &mut s_ser, &mut c_ser);
        for w in [2usize, 3, 8] {
            let mut s_par = Mat::zeros(p, 4);
            let mut c_par = Mat::zeros(p, 4);
            accumulate_center_update_rows(&chunks, &assign, &mut s_par, &mut c_par, w);
            for (a, b) in s_ser.as_slice().iter().zip(s_par.as_slice()) {
                assert_eq!(a.to_bits(), b.to_bits(), "sums, workers={w}");
            }
            for (a, b) in c_ser.as_slice().iter().zip(c_par.as_slice()) {
                assert_eq!(a.to_bits(), b.to_bits(), "counts, workers={w}");
            }
        }
    }

    #[test]
    fn assign_into_default_and_parallel_agree() {
        // 4400 samples: enough for a real 4-way fan-out past the
        // MIN_ASSIGN_COLS_PER_WORKER gate
        let n = 4400usize;
        let mut rng = Pcg64::seed(53);
        let d = gaussian_blobs(32, n, 3, 0.2, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.25, transform: TransformKind::Hadamard, seed: 9 };
        let sp = Sparsifier::new(32, cfg).unwrap();
        let chunk = sp.compress_chunk(&d.data, 0).unwrap();
        let mut rng2 = Pcg64::seed(54);
        let centers = sp.precondition_dense(&kmeans_pp_sparse_seed(&chunk, 3, &mut rng2));
        let (ids_ref, obj_ref) = NativeAssigner.assign(&chunk, &centers).unwrap();
        for w in [1usize, 4] {
            let mut ids = vec![0u32; n];
            let mut dist = vec![0.0f64; n];
            NativeAssigner.assign_into(&chunk, &centers, w, &mut ids, &mut dist).unwrap();
            assert_eq!(ids, ids_ref, "workers={w}");
            let obj: f64 = dist.iter().sum();
            assert_eq!(obj.to_bits(), obj_ref.to_bits(), "workers={w}");
        }
    }

    /// Dense seed helper for the assigner test (original-domain columns).
    fn kmeans_pp_sparse_seed(chunk: &SparseChunk, k: usize, rng: &mut Pcg64) -> Mat {
        let dense = chunk.to_dense();
        let mut centers = Mat::zeros(dense.rows(), k);
        for c in 0..k {
            let pick = rng.next_range(dense.cols() as u32) as usize;
            centers.col_mut(c).copy_from_slice(dense.col(pick));
        }
        centers
    }

    #[test]
    fn solve_centers_keeps_prev_on_unseen() {
        let sums = Mat::from_vec(2, 1, vec![4.0, 0.0]).unwrap();
        let counts = Mat::from_vec(2, 1, vec![2.0, 0.0]).unwrap();
        let prev = Mat::from_vec(2, 1, vec![9.0, 7.5]).unwrap();
        let out = solve_centers(&sums, &counts, &prev);
        assert_eq!(out.get(0, 0), 2.0);
        assert_eq!(out.get(1, 0), 7.5);
    }

    #[test]
    fn higher_gamma_does_not_hurt_much() {
        let (lo, labels_lo) = fit(0.05, 51, 900);
        let (hi, labels_hi) = fit(0.5, 51, 900);
        let acc_lo = clustering_accuracy(&lo.assign, &labels_lo, 3);
        let acc_hi = clustering_accuracy(&hi.assign, &labels_hi, 3);
        assert!(acc_hi >= acc_lo - 0.05, "γ=0.5 acc {acc_hi} vs γ=0.05 acc {acc_lo}");
    }
}
