//! K-means: the standard dense algorithm, k-means++ seeding, and the
//! paper's **sparsified K-means** (Algorithm 1) with its two-pass
//! refinement (Algorithm 2).
//!
//! The sparsified fit is source-driven end to end: seeding and every
//! Lloyd iteration fold chunk-by-chunk through the [`CenterStep`]
//! kernel from any rewindable
//! [`SparseChunkSource`](crate::sparse::SparseChunkSource), so the fit
//! runs out-of-core ([`SparsifiedKmeans::fit_source`]) bitwise-identical
//! to the in-memory path ([`SparsifiedKmeans::fit_chunks`]).

mod center_step;
mod dense;
pub(crate) mod plusplus;
mod sparsified;
mod twopass;

pub use center_step::CenterStep;
pub use dense::{assign_dense, kmeans_dense, lloyd_once_dense};
pub use plusplus::{kmeans_pp_dense, kmeans_pp_sparse, kmeans_pp_sparse_chunks};
pub use sparsified::{
    accumulate_center_update, solve_centers, NativeAssigner, SparseAssigner, SparsifiedKmeans,
    SparsifiedModel, CENTER_BOUND_DELTA,
};
pub use twopass::two_pass_refine;

use crate::linalg::Mat;

/// Options shared by every K-means variant.
#[derive(Clone, Copy, Debug)]
pub struct KmeansOpts {
    /// Maximum Lloyd iterations per start.
    pub max_iters: usize,
    /// Convergence: stop when fewer than `tol_frac·n` assignments change.
    pub tol_frac: f64,
    /// Number of k-means++ restarts; the best objective wins (the paper
    /// uses 20 for small tests, 10 for big-data).
    pub n_init: usize,
    /// Seed for seeding + restarts.
    pub seed: u64,
}

impl Default for KmeansOpts {
    fn default() -> Self {
        KmeansOpts { max_iters: 100, tol_frac: 0.0, n_init: 1, seed: 0 }
    }
}

/// Output of any K-means variant.
#[derive(Clone, Debug)]
pub struct KmeansResult {
    /// Cluster centers in the **original** data domain (p_orig × K).
    pub centers: Mat,
    /// Per-sample cluster ids.
    pub assign: Vec<u32>,
    /// Final objective value (sum of squared distances in the domain the
    /// algorithm optimizes — Eq. 28 for dense, Eq. 34 for sparsified).
    pub objective: f64,
    /// Lloyd iterations used (best restart).
    pub iterations: usize,
    /// Whether the best restart converged before `max_iters`.
    pub converged: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian_blobs;
    use crate::metrics::clustering_accuracy;
    use crate::rng::Pcg64;

    #[test]
    fn dense_kmeans_recovers_blobs() {
        let mut rng = Pcg64::seed(2);
        let d = gaussian_blobs(16, 400, 3, 0.05, &mut rng);
        let res = kmeans_dense(&d.data, 3, KmeansOpts { n_init: 4, ..Default::default() });
        let acc = clustering_accuracy(&res.assign, &d.labels, 3);
        assert!(acc > 0.98, "accuracy {acc}");
        assert!(res.converged);
    }

    #[test]
    fn objective_never_increases_across_restarts() {
        let mut rng = Pcg64::seed(4);
        let d = gaussian_blobs(8, 150, 4, 0.3, &mut rng);
        let one = kmeans_dense(&d.data, 4, KmeansOpts { n_init: 1, ..Default::default() });
        let many = kmeans_dense(&d.data, 4, KmeansOpts { n_init: 6, ..Default::default() });
        assert!(many.objective <= one.objective + 1e-9);
    }
}
