//! Standard (dense) Lloyd K-means — the paper's uncompressed baseline.
//!
//! The assignment step uses the expansion
//! `‖x − μ‖² = ‖x‖² − 2 xᵀμ + ‖μ‖²`; the cross term is a blocked
//! matrix product so the inner loop is a gemm, the same optimization the
//! paper's "optimized variant of Matlab's kmeans" applies.

use crate::linalg::Mat;
use crate::rng::Pcg64;

use super::{plusplus::kmeans_pp_dense, KmeansOpts, KmeansResult};

/// Assign every column of `x` to the nearest center; returns assignments
/// and the summed min squared distance (the Eq. 28 objective).
pub fn assign_dense(x: &Mat, centers: &Mat) -> (Vec<u32>, f64) {
    let n = x.cols();
    let k = centers.cols();
    // center norms
    let cnorm: Vec<f64> = (0..k)
        .map(|c| centers.col(c).iter().map(|v| v * v).sum())
        .collect();
    let cross = x.matmul_transa(centers); // n×k : xᵀμ
    let mut assign = vec![0u32; n];
    let mut obj = 0.0;
    for j in 0..n {
        let xn: f64 = x.col(j).iter().map(|v| v * v).sum();
        let mut best = f64::INFINITY;
        let mut arg = 0u32;
        for c in 0..k {
            let d = xn - 2.0 * cross.get(j, c) + cnorm[c];
            if d < best {
                best = d;
                arg = c as u32;
            }
        }
        assign[j] = arg;
        obj += best.max(0.0);
    }
    (assign, obj)
}

/// One Lloyd iteration: assignment + center update. Empty clusters keep
/// their previous center. Returns (assignments, objective, changed count).
pub fn lloyd_once_dense(
    x: &Mat,
    centers: &mut Mat,
    prev_assign: Option<&[u32]>,
) -> (Vec<u32>, f64, usize) {
    let (assign, obj) = assign_dense(x, centers);
    let changed = match prev_assign {
        Some(prev) => assign.iter().zip(prev).filter(|(a, b)| a != b).count(),
        None => assign.len(),
    };
    let p = x.rows();
    let k = centers.cols();
    let mut sums = Mat::zeros(p, k);
    let mut counts = vec![0usize; k];
    for (j, &c) in assign.iter().enumerate() {
        counts[c as usize] += 1;
        let col = x.col(j);
        let s = sums.col_mut(c as usize);
        for i in 0..p {
            s[i] += col[i];
        }
    }
    for c in 0..k {
        if counts[c] > 0 {
            let inv = 1.0 / counts[c] as f64;
            let (s, dst) = (sums.col(c), centers.col_mut(c));
            for i in 0..p {
                dst[i] = s[i] * inv;
            }
        }
    }
    (assign, obj, changed)
}

/// Full dense K-means with k-means++ restarts.
pub fn kmeans_dense(x: &Mat, k: usize, opts: KmeansOpts) -> KmeansResult {
    let n = x.cols();
    let mut best: Option<KmeansResult> = None;
    for start in 0..opts.n_init.max(1) {
        let mut rng = Pcg64::seed_stream(opts.seed, start as u64);
        let mut centers = kmeans_pp_dense(x, k, &mut rng);
        let mut assign: Vec<u32> = Vec::new();
        let mut obj = f64::INFINITY;
        let mut iterations = 0;
        let mut converged = false;
        for it in 0..opts.max_iters {
            let prev = if assign.is_empty() { None } else { Some(assign.as_slice()) };
            let (a, o, changed) = lloyd_once_dense(x, &mut centers, prev);
            assign = a;
            obj = o;
            iterations = it + 1;
            if (changed as f64) <= opts.tol_frac * n as f64 {
                converged = true;
                break;
            }
        }
        let candidate = KmeansResult { centers, assign, objective: obj, iterations, converged };
        if best.as_ref().map_or(true, |b| candidate.objective < b.objective) {
            best = Some(candidate);
        }
    }
    best.expect("n_init >= 1")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian_blobs;
    use crate::rng::Pcg64;

    #[test]
    fn assign_matches_bruteforce() {
        let mut rng = Pcg64::seed(1);
        let x = Mat::from_fn(5, 30, |_, _| rng.normal());
        let centers = Mat::from_fn(5, 4, |_, _| rng.normal());
        let (assign, obj) = assign_dense(&x, &centers);
        let mut want_obj = 0.0;
        for j in 0..30 {
            let mut best = (f64::INFINITY, 0u32);
            for c in 0..4 {
                let d: f64 = x
                    .col(j)
                    .iter()
                    .zip(centers.col(c))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if d < best.0 {
                    best = (d, c as u32);
                }
            }
            assert_eq!(assign[j], best.1, "col {j}");
            want_obj += best.0;
        }
        assert!((obj - want_obj).abs() < 1e-8);
    }

    #[test]
    fn lloyd_monotonically_decreases_objective() {
        let mut rng = Pcg64::seed(3);
        let d = gaussian_blobs(6, 200, 3, 0.4, &mut rng);
        let mut centers = kmeans_pp_dense(&d.data, 3, &mut rng);
        let mut last = f64::INFINITY;
        let mut assign: Vec<u32> = Vec::new();
        for _ in 0..10 {
            let prev = if assign.is_empty() { None } else { Some(assign.as_slice()) };
            let (a, obj, _) = lloyd_once_dense(&d.data, &mut centers, prev);
            assign = a;
            assert!(obj <= last + 1e-9, "objective increased: {obj} > {last}");
            last = obj;
        }
    }

    #[test]
    fn empty_cluster_keeps_center() {
        // two far blobs, three centers: one center will starve but must
        // remain finite
        let mut rng = Pcg64::seed(5);
        let d = gaussian_blobs(4, 60, 2, 0.01, &mut rng);
        let res = kmeans_dense(&d.data, 3, KmeansOpts { n_init: 2, ..Default::default() });
        assert!(res.centers.as_slice().iter().all(|v| v.is_finite()));
    }
}
