//! The `CenterStep` kernel: one Lloyd iteration folded chunk-by-chunk
//! from any rewindable chunk stream — the K-means mirror of
//! [`SparseCovOp`](crate::estimators::SparseCovOp)'s dot/scatter split.
//!
//! Each [`fold`](CenterStep::fold) runs two phases on one chunk:
//!
//! 1. **dot** (Eq. 36): per-sample masked-distance assignment through a
//!    [`SparseAssigner`] — pure per sample, so neither chunk granularity
//!    nor the assigner's fan-out can change a single bit;
//! 2. **scatter** (Eq. 39): the masked center sums/counts update. Each
//!    worker owns a fixed contiguous *row range* of the accumulators for
//!    the whole pass and locates its slice of every sample's sorted index
//!    list by binary search, so every accumulator cell receives its
//!    contributions in global sample order — the same order as the serial
//!    loop — regardless of worker count **and** of where the chunk
//!    boundaries fall (a store reader's memory budget changes boundaries,
//!    never bits).
//!
//! One pass per Lloyd iteration, O(p·k·workers) accumulator state plus
//! 12 bytes per sample (assignment + distance), and **no** requirement
//! that the sparse matrix is ever resident: this is what lets
//! [`SparsifiedKmeans::fit_source`](super::SparsifiedKmeans::fit_source)
//! run out-of-core over a memory-budgeted
//! [`SparseStoreReader`](crate::store::SparseStoreReader) while staying
//! bitwise identical to the in-memory
//! [`fit_chunks`](super::SparsifiedKmeans::fit_chunks) path.

use std::ops::Range;

use crate::error::{invalid, Result};
use crate::linalg::Mat;
use crate::parallel;
use crate::sparse::{SparseChunk, SparseChunkSource};

use super::{solve_centers, SparseAssigner};

/// Below this many columns the scatter runs its range jobs inline — the
/// fork overhead beats the work (bitwise identical either way).
const MIN_CENTER_COLS: usize = 256;

/// A rewindable stream of borrowed chunks — the internal walking
/// abstraction the Lloyd loop and the k-means++ seeding share. One
/// [`walk`](ChunkWalk::walk) call is one pass in global column order; the
/// visitor returns `Ok(false)` to stop the pass early (used by the
/// seeding's single-column fetch).
pub(crate) trait ChunkWalk {
    /// Run one pass, feeding every chunk to `f` in global column order.
    fn walk(&mut self, f: &mut dyn FnMut(&SparseChunk) -> Result<bool>) -> Result<()>;
}

/// Borrowing walk over in-memory chunks (no clones — the slice is the
/// storage).
pub(crate) struct SliceWalk<'a>(pub(crate) &'a [SparseChunk]);

impl ChunkWalk for SliceWalk<'_> {
    fn walk(&mut self, f: &mut dyn FnMut(&SparseChunk) -> Result<bool>) -> Result<()> {
        for chunk in self.0 {
            if !f(chunk)? {
                return Ok(());
            }
        }
        Ok(())
    }
}

/// Walk over any [`SparseChunkSource`]; counts the passes it makes (the
/// raw material of `FitReport`'s sparse-pass accounting).
pub(crate) struct SourceWalk<'a> {
    source: &'a mut dyn SparseChunkSource,
    /// Passes started so far (each `walk` call resets the source).
    pub(crate) passes: usize,
}

impl<'a> SourceWalk<'a> {
    pub(crate) fn new(source: &'a mut dyn SparseChunkSource) -> Self {
        SourceWalk { source, passes: 0 }
    }
}

impl ChunkWalk for SourceWalk<'_> {
    fn walk(&mut self, f: &mut dyn FnMut(&SparseChunk) -> Result<bool>) -> Result<()> {
        self.source.reset()?;
        self.passes += 1;
        while let Some(chunk) = self.source.next_chunk()? {
            if !f(&chunk)? {
                return Ok(());
            }
        }
        Ok(())
    }
}

/// Scatter kernel over one contiguous accumulator row range `[lo, hi)`:
/// fold one chunk's masked sums/counts contributions, visiting cells in
/// global sample order. `s` / `cnt` are the range's column-major
/// `rows × k` panels.
fn scatter_range(
    chunk: &SparseChunk,
    assign: &[u32],
    r: Range<usize>,
    s: &mut [f64],
    cnt: &mut [f64],
) {
    let rows = r.len();
    let (lo, hi) = (r.start as u32, r.end as u32);
    for i in 0..chunk.n() {
        let c = assign[i] as usize;
        let idx = chunk.col_indices(i);
        let vals = chunk.col_values(i);
        let a_lo = idx.partition_point(|&j| j < lo);
        let a_hi = a_lo + idx[a_lo..].partition_point(|&j| j < hi);
        if a_lo == a_hi {
            continue;
        }
        let scol = &mut s[c * rows..(c + 1) * rows];
        let ccol = &mut cnt[c * rows..(c + 1) * rows];
        for a in a_lo..a_hi {
            let j = (idx[a] - lo) as usize;
            scol[j] += vals[a];
            ccol[j] += 1.0;
        }
    }
}

/// One Lloyd iteration as a chunk-fold: assignment (the **dot** phase,
/// Eq. 36) + center accumulation (the **scatter** phase, Eq. 39),
/// source-driven — the K-means mirror of
/// [`SparseCovOp`](crate::estimators::SparseCovOp)'s split. Every
/// accumulator cell receives its contributions in global sample order
/// (fixed row ranges + per-sample binary search), so results are bitwise
/// invariant to the worker count and to chunk granularity.
///
/// Lifecycle per iteration: [`begin`](Self::begin) → one
/// [`fold`](Self::fold) per chunk (in global column order) →
/// [`assign`](Self::assign) / [`objective`](Self::objective) /
/// [`cluster_sizes`](Self::cluster_sizes) / [`solve`](Self::solve).
pub struct CenterStep {
    p: usize,
    k: usize,
    workers: usize,
    /// Fixed row partition of `0..p` — one entry per scatter worker.
    ranges: Vec<Range<usize>>,
    /// Per-range masked sums panel (`rows × k`, column-major).
    sums: Vec<Vec<f64>>,
    /// Per-range observation counts panel (same layout).
    counts: Vec<Vec<f64>>,
    /// Per-sample assignments for the pass so far.
    assign: Vec<u32>,
    /// Per-sample min masked distances (summed in sample order at the
    /// end of the pass, so the objective is granularity-invariant).
    dist: Vec<f64>,
}

impl CenterStep {
    /// Kernel for dimension `p`, `k` clusters, a fan-out of `workers`.
    pub fn new(p: usize, k: usize, workers: usize) -> Self {
        let ranges = parallel::split_ranges(p, workers.max(1));
        let sums = ranges.iter().map(|r| vec![0.0; r.len() * k]).collect();
        let counts = ranges.iter().map(|r| vec![0.0; r.len() * k]).collect();
        CenterStep {
            p,
            k,
            workers: workers.max(1),
            ranges,
            sums,
            counts,
            assign: Vec::new(),
            dist: Vec::new(),
        }
    }

    /// Start a fresh iteration: zero the accumulators, forget the pass
    /// state (buffer capacity is retained across iterations).
    pub fn begin(&mut self) {
        for s in &mut self.sums {
            s.fill(0.0);
        }
        for c in &mut self.counts {
            c.fill(0.0);
        }
        self.assign.clear();
        self.dist.clear();
    }

    /// Fold one chunk: assign its columns against `centers`, then
    /// accumulate the masked center update under that assignment.
    pub fn fold(
        &mut self,
        chunk: &SparseChunk,
        centers: &Mat,
        assigner: &dyn SparseAssigner,
    ) -> Result<()> {
        if chunk.p() != self.p {
            return invalid(format!(
                "CenterStep: chunk p={} does not match kernel p={}",
                chunk.p(),
                self.p
            ));
        }
        debug_assert_eq!(centers.cols(), self.k);
        let off = self.assign.len();
        let cn = chunk.n();
        self.assign.resize(off + cn, 0);
        self.dist.resize(off + cn, 0.0);
        // dot phase: per-sample, partition-free
        assigner.assign_into(
            chunk,
            centers,
            self.workers,
            &mut self.assign[off..off + cn],
            &mut self.dist[off..off + cn],
        )?;
        // scatter phase: fixed row ranges, per-cell global sample order
        let assign = &self.assign[off..off + cn];
        let jobs: Vec<(Range<usize>, &mut [f64], &mut [f64])> = self
            .ranges
            .iter()
            .cloned()
            .zip(self.sums.iter_mut())
            .zip(self.counts.iter_mut())
            .map(|((r, s), c)| (r, s.as_mut_slice(), c.as_mut_slice()))
            .collect();
        if jobs.len() <= 1 || cn < MIN_CENTER_COLS {
            for (r, s, c) in jobs {
                scatter_range(chunk, assign, r, s, c);
            }
        } else {
            crossbeam_utils::thread::scope(|scope| {
                let mut iter = jobs.into_iter();
                let first = iter.next().expect("len > 1");
                let handles: Vec<_> = iter
                    .map(|(r, s, c)| {
                        scope.spawn(move |_| scatter_range(chunk, assign, r, s, c))
                    })
                    .collect();
                let (r, s, c) = first;
                scatter_range(chunk, assign, r, s, c);
                for h in handles {
                    h.join().expect("center scatter worker panicked");
                }
            })
            .expect("center scatter scope panicked");
        }
        Ok(())
    }

    /// Samples folded so far this iteration.
    pub fn n(&self) -> usize {
        self.assign.len()
    }

    /// Per-sample assignments of the completed pass (global order).
    pub fn assign(&self) -> &[u32] {
        &self.assign
    }

    /// The Eq. 34 objective: per-sample min masked distances reduced in
    /// sample order (independent of chunking and fan-out).
    pub fn objective(&self) -> f64 {
        self.dist.iter().sum()
    }

    /// Members per cluster under the completed pass's assignment.
    pub fn cluster_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.k];
        for &a in &self.assign {
            sizes[a as usize] += 1;
        }
        sizes
    }

    /// Assemble the per-range accumulator panels into dense `p × k`
    /// masked-sum and count matrices — the iteration's raw Eq. 39 state,
    /// in a worker-layout-independent form. This is what a distributed
    /// partial ships to the coordinator: summing exported matrices from
    /// disjoint sample sets equals one process folding all the samples,
    /// up to f64 re-association (exact when partials are kept per shard
    /// and folded in shard order).
    pub fn export_update(&self) -> (Mat, Mat) {
        let mut sums = Mat::zeros(self.p, self.k);
        let mut counts = Mat::zeros(self.p, self.k);
        for (t, r) in self.ranges.iter().enumerate() {
            let rows = r.len();
            for c in 0..self.k {
                sums.col_mut(c)[r.start..r.end]
                    .copy_from_slice(&self.sums[t][c * rows..(c + 1) * rows]);
                counts.col_mut(c)[r.start..r.end]
                    .copy_from_slice(&self.counts[t][c * rows..(c + 1) * rows]);
            }
        }
        (sums, counts)
    }

    /// Assemble the accumulated sums/counts and solve the Eq. 39/40
    /// diagonal system (`prev` supplies entries for never-sampled
    /// coordinates).
    pub fn solve(&self, prev: &Mat) -> Mat {
        let (sums, counts) = self.export_update();
        solve_centers(&sums, &counts, prev)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{accumulate_center_update, NativeAssigner};
    use super::*;
    use crate::data::gaussian_blobs;
    use crate::rng::Pcg64;
    use crate::sampling::{Sparsifier, SparsifyConfig};
    use crate::transform::TransformKind;

    fn compressed(n: usize, split_at: &[usize]) -> (Sparsifier, Vec<SparseChunk>) {
        let mut rng = Pcg64::seed(77);
        let d = gaussian_blobs(64, n, 4, 0.2, &mut rng);
        let cfg = SparsifyConfig { gamma: 0.2, transform: TransformKind::Hadamard, seed: 5 };
        let sp = Sparsifier::new(64, cfg).unwrap();
        let mut chunks = Vec::new();
        let mut a = 0usize;
        for &b in split_at.iter().chain(std::iter::once(&n)) {
            if b > a {
                chunks.push(sp.compress_chunk(&d.data.col_range(a, b), a).unwrap());
                a = b;
            }
        }
        (sp, chunks)
    }

    /// Reference iteration: serial assignment + the fused serial center
    /// update kernel, exactly the pre-CenterStep code path.
    fn reference_step(
        sp: &Sparsifier,
        chunks: &[SparseChunk],
        centers: &Mat,
        k: usize,
    ) -> (Vec<u32>, f64, Mat) {
        let n: usize = chunks.iter().map(|c| c.n()).sum();
        let mut assign = vec![0u32; n];
        let mut dist = vec![0.0f64; n];
        let mut off = 0usize;
        for chunk in chunks {
            NativeAssigner::new()
                .assign_into(
                    chunk,
                    centers,
                    1,
                    &mut assign[off..off + chunk.n()],
                    &mut dist[off..off + chunk.n()],
                )
                .unwrap();
            off += chunk.n();
        }
        let mut sums = Mat::zeros(sp.p(), k);
        let mut counts = Mat::zeros(sp.p(), k);
        let mut off = 0usize;
        for chunk in chunks {
            accumulate_center_update(chunk, &assign[off..off + chunk.n()], &mut sums, &mut counts);
            off += chunk.n();
        }
        let next = solve_centers(&sums, &counts, centers);
        (assign, dist.iter().sum(), next)
    }

    #[test]
    fn fold_matches_reference_for_any_granularity_and_workers() {
        let k = 4;
        let (sp, whole) = compressed(700, &[]);
        let mut rng = Pcg64::seed(3);
        let centers = Mat::from_fn(sp.p(), k, |_, _| rng.normal());
        let (a_ref, obj_ref, next_ref) = reference_step(&sp, &whole, &centers, k);
        for (splits, workers) in [
            (vec![], 1usize),
            (vec![100, 350], 1),
            (vec![100, 350], 3),
            (vec![1, 2, 3, 699], 4),
            (vec![350], 8),
        ] {
            let (_, chunks) = compressed(700, &splits);
            let mut step = CenterStep::new(sp.p(), k, workers);
            step.begin();
            for c in &chunks {
                step.fold(c, &centers, &NativeAssigner::new()).unwrap();
            }
            assert_eq!(step.n(), 700);
            assert_eq!(step.assign(), &a_ref[..], "splits {splits:?} workers {workers}");
            assert_eq!(
                step.objective().to_bits(),
                obj_ref.to_bits(),
                "objective, splits {splits:?} workers {workers}"
            );
            let next = step.solve(&centers);
            for (a, b) in next.as_slice().iter().zip(next_ref.as_slice()) {
                assert_eq!(a.to_bits(), b.to_bits(), "splits {splits:?} workers {workers}");
            }
        }
    }

    #[test]
    fn begin_resets_for_the_next_iteration() {
        let k = 4;
        let (sp, chunks) = compressed(300, &[120]);
        let mut rng = Pcg64::seed(9);
        let centers = Mat::from_fn(sp.p(), k, |_, _| rng.normal());
        let mut step = CenterStep::new(sp.p(), k, 2);
        step.begin();
        for c in &chunks {
            step.fold(c, &centers, &NativeAssigner::new()).unwrap();
        }
        let first = (step.assign().to_vec(), step.objective());
        step.begin();
        assert_eq!(step.n(), 0);
        for c in &chunks {
            step.fold(c, &centers, &NativeAssigner::new()).unwrap();
        }
        assert_eq!(step.assign(), &first.0[..]);
        assert_eq!(step.objective().to_bits(), first.1.to_bits());
        let sizes = step.cluster_sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 300);
    }

    #[test]
    fn fold_rejects_mismatched_chunk() {
        let cfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 1 };
        let sp = Sparsifier::new(16, cfg).unwrap();
        let chunk = sp.compress_chunk(&Mat::zeros(16, 3), 0).unwrap();
        let mut step = CenterStep::new(32, 2, 1);
        step.begin();
        let centers = Mat::zeros(32, 2);
        assert!(step.fold(&chunk, &centers, &NativeAssigner::new()).is_err());
    }
}
