//! Deterministic pseudo-randomness for the whole pipeline.
//!
//! Everything stochastic in the library (sign diagonals, sampling masks,
//! synthetic data, k-means++ seeding, baselines) draws from [`Pcg64`],
//! seeded explicitly. Per-column streams are derived with [`Pcg64::fork`]
//! from `(seed, global column index)` so results are independent of chunk
//! boundaries and worker scheduling — a load-bearing property for the
//! coordinator's reproducibility tests.

mod dist;
mod pcg;

pub use dist::*;
pub use pcg::Pcg64;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seed(123);
        let mut b = Pcg64::seed(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed(1);
        let mut b = Pcg64::seed(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same == 0, "streams should diverge");
    }

    #[test]
    fn fork_streams_are_independent_of_draw_order() {
        let base = Pcg64::seed(7);
        let mut f3 = base.fork(3);
        let first = f3.next_u64();
        // draw from other forks in between; fork(3) must be unaffected
        let mut f1 = base.fork(1);
        let _ = f1.next_u64();
        let mut f3b = base.fork(3);
        assert_eq!(first, f3b.next_u64());
    }

    #[test]
    fn uniform_f64_in_unit_interval() {
        let mut r = Pcg64::seed(9);
        for _ in 0..10_000 {
            let u = r.next_f64();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_variance() {
        let mut r = Pcg64::seed(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let u = r.next_f64();
            s += u;
            s2 += u * u;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var={var}");
    }

    #[test]
    fn range_is_unbiasedish_and_in_bounds() {
        let mut r = Pcg64::seed(13);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            let v = r.next_range(7) as usize;
            counts[v] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seed(17);
        let n = 200_000;
        let (mut s, mut s2, mut s4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s += z;
            s2 += z * z;
            s4 += z * z * z * z;
        }
        let nf = n as f64;
        assert!((s / nf).abs() < 0.01);
        assert!((s2 / nf - 1.0).abs() < 0.02);
        assert!((s4 / nf - 3.0).abs() < 0.15, "kurtosis {}", s4 / nf);
    }

    #[test]
    fn signs_are_pm_one_and_balanced() {
        let mut r = Pcg64::seed(19);
        let s = signs(4096, &mut r);
        assert!(s.iter().all(|&v| v == 1.0 || v == -1.0));
        let pos = s.iter().filter(|&&v| v > 0.0).count() as f64;
        assert!((pos / 4096.0 - 0.5).abs() < 0.05);
    }

    #[test]
    fn chi2_mean_matches_dof() {
        let mut r = Pcg64::seed(23);
        let k = 5.0;
        let n = 50_000;
        let mean = (0..n).map(|_| r.chi2(k)).sum::<f64>() / n as f64;
        assert!((mean - k).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Pcg64::seed(29);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
