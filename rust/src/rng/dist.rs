//! Distributions layered on [`Pcg64`]: normal (polar Box–Muller with a
//! cached spare), gamma (Marsaglia–Tsang), chi-square, Student-t — the
//! generators the paper's synthetic experiments need (Gaussian noise,
//! spiked-covariance coefficients, multivariate-t with 1 dof for Fig. 1).

use super::Pcg64;

impl Pcg64 {
    /// Standard normal via polar Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Gamma(shape `a` > 0, scale 1) via Marsaglia–Tsang (with the
    /// `a < 1` boost `Gamma(a) = Gamma(a+1) * U^{1/a}`).
    pub fn gamma(&mut self, a: f64) -> f64 {
        assert!(a > 0.0, "gamma shape must be positive");
        if a < 1.0 {
            let g = self.gamma(a + 1.0);
            let u = self.next_f64().max(f64::MIN_POSITIVE);
            return g * u.powf(1.0 / a);
        }
        let d = a - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v3;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
                return d * v3;
            }
        }
    }

    /// Chi-square with `k` degrees of freedom (k may be fractional).
    pub fn chi2(&mut self, k: f64) -> f64 {
        2.0 * self.gamma(0.5 * k)
    }

    /// Student-t with `df` degrees of freedom. `df = 1` is Cauchy — the
    /// heavy-tailed regime of the paper's Fig. 1 experiment.
    pub fn student_t(&mut self, df: f64) -> f64 {
        self.normal() / (self.chi2(df) / df).sqrt()
    }

    /// Fill `out` with iid standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }
}

/// A vector of iid Rademacher signs (`±1.0`), the diagonal of the ROS `D`.
pub fn signs(p: usize, rng: &mut Pcg64) -> Vec<f64> {
    let mut out = Vec::with_capacity(p);
    let mut bits = 0u64;
    for i in 0..p {
        if i % 64 == 0 {
            bits = rng.next_u64();
        }
        out.push(if bits & 1 == 1 { 1.0 } else { -1.0 });
        bits >>= 1;
    }
    out
}

/// Sample a categorical index from (unnormalized, nonnegative) weights.
/// Used by k-means++ (D² weighting) and leverage-score row sampling.
pub fn weighted_index(weights: &[f64], rng: &mut Pcg64) -> usize {
    let total: f64 = weights.iter().sum();
    debug_assert!(total.is_finite());
    if total <= 0.0 {
        // degenerate (all-zero weights): fall back to uniform
        return rng.next_range(weights.len() as u32) as usize;
    }
    let mut u = rng.next_f64() * total;
    for (i, &w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return i;
        }
    }
    weights.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn student_t1_is_heavy_tailed() {
        let mut r = Pcg64::seed(101);
        let n = 20_000;
        let big = (0..n).filter(|_| r.student_t(1.0).abs() > 20.0).count() as f64 / n as f64;
        // P(|Cauchy| > 20) = 2/pi * atan(1/20) ≈ 0.0318
        assert!((big - 0.0318).abs() < 0.01, "tail mass {big}");
    }

    #[test]
    fn gamma_mean_variance() {
        let mut r = Pcg64::seed(103);
        let a = 3.7;
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gamma(a)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - a).abs() < 0.08, "mean {mean}");
        assert!((var - a).abs() < 0.25, "var {var}");
    }

    #[test]
    fn gamma_small_shape() {
        let mut r = Pcg64::seed(105);
        let a = 0.4;
        let n = 50_000;
        let mean = (0..n).map(|_| r.gamma(a)).sum::<f64>() / n as f64;
        assert!((mean - a).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Pcg64::seed(107);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[weighted_index(&w, &mut r)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "ratio {ratio}");
    }

    #[test]
    fn weighted_index_all_zero_falls_back_uniform() {
        let mut r = Pcg64::seed(109);
        let w = [0.0, 0.0, 0.0, 0.0];
        for _ in 0..100 {
            assert!(weighted_index(&w, &mut r) < 4);
        }
    }
}
