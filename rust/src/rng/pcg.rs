//! PCG-XSH-RR 64/32 with a 64-bit output wrapper and SplitMix64 seeding.
//!
//! Small, fast, statistically solid for simulation workloads, and — unlike
//! `rand` — available in this offline build. Stream selection (the PCG
//! increment) backs [`Pcg64::fork`] for per-column derived generators.

/// SplitMix64: used to expand user seeds into full generator state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Permuted congruential generator (PCG-XSH-RR 64/32).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
    /// Root seed, retained so [`fork`](Self::fork) derives child streams
    /// from the *original* entropy rather than the current position.
    root: u64,
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream 0).
    pub fn seed(seed: u64) -> Self {
        Self::seed_stream(seed, 0)
    }

    /// Create a generator from a seed and stream id. Distinct streams from
    /// the same seed are statistically independent.
    pub fn seed_stream(seed: u64, stream: u64) -> Self {
        let mut sm = seed ^ 0x5851_F42D_4C95_7F2D ^ stream.wrapping_mul(0xA24B_AED4_963E_E407);
        let init_state = splitmix64(&mut sm);
        let init_inc = splitmix64(&mut sm) | 1; // must be odd
        let mut g = Pcg64 { state: 0, inc: init_inc, root: seed };
        g.state = init_state.wrapping_add(g.inc);
        let _ = g.next_u32();
        g
    }

    /// Derive an independent child stream, keyed on the *root* seed and the
    /// given index — independent of how much this generator has been used.
    pub fn fork(&self, index: u64) -> Self {
        Self::seed_stream(self.root, index.wrapping_add(1))
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 random bits (two 32-bit outputs).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire's rejection method).
    #[inline]
    pub fn next_range(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64).wrapping_mul(bound as u64);
            let lo = m as u32;
            if lo >= bound {
                return (m >> 32) as u32;
            }
            // threshold = (2^32 - bound) mod bound = -bound mod bound
            let t = bound.wrapping_neg() % bound;
            if lo >= t {
                return (m >> 32) as u32;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.next_range(i as u32 + 1) as usize;
            v.swap(i, j);
        }
    }
}
