//! The `.pdsp` partial-artifact envelope and payload codec primitives.
//!
//! Layout (all integers little-endian; full spec in `docs/FORMAT.md`):
//!
//! ```text
//! offset  size  field
//! 0       4     magic "PDSP"
//! 4       4     u32 payload format version (per kind)
//! 8       4     u32 kind tag (see distributed::kind)
//! 12      8     u64 payload length
//! 20      len   payload bytes
//! 20+len  4     u32 CRC-32 (IEEE) over bytes [0, 20+len)
//! ```
//!
//! Decoding distinguishes damage from incompatibility: truncation, bad
//! magic, CRC mismatch, and trailing bytes are
//! [`Error::Corrupt`](crate::error::Error::Corrupt); an unexpected kind
//! or a newer-than-this-build version is
//! [`Error::Invalid`](crate::error::Error::Invalid).

use crate::convert::usize_to_u64;
use crate::error::{corrupt, Result};
use crate::store::crc32;

/// Envelope magic.
const MAGIC: [u8; 4] = *b"PDSP";
/// Bytes before the payload.
const HEADER_LEN: usize = 20;

/// Little-endian `u32` at `off`; the caller has already bounds-checked
/// `off + 4 <= bytes.len()`, and element indexing keeps this panic-free
/// in practice without an `expect` on a slice-to-array conversion.
fn le_u32_at(bytes: &[u8], off: usize) -> u32 {
    u32::from_le_bytes([bytes[off], bytes[off + 1], bytes[off + 2], bytes[off + 3]])
}

/// Little-endian `u64` at `off` (caller bounds-checked `off + 8`).
fn le_u64_at(bytes: &[u8], off: usize) -> u64 {
    u64::from_le_bytes([
        bytes[off],
        bytes[off + 1],
        bytes[off + 2],
        bytes[off + 3],
        bytes[off + 4],
        bytes[off + 5],
        bytes[off + 6],
        bytes[off + 7],
    ])
}

/// Wrap a payload in the `.pdsp` envelope.
pub fn encode_artifact(kind: u32, version: u32, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + 4);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&version.to_le_bytes());
    out.extend_from_slice(&kind.to_le_bytes());
    out.extend_from_slice(&usize_to_u64(payload.len()).to_le_bytes());
    out.extend_from_slice(payload);
    let c = crc32(&out);
    out.extend_from_slice(&c.to_le_bytes());
    out
}

/// Unwrap a `.pdsp` envelope: returns `(version, kind, payload)`.
pub fn decode_artifact(bytes: &[u8]) -> Result<(u32, u32, &[u8])> {
    if bytes.len() < HEADER_LEN + 4 {
        return corrupt(format!(
            "partial artifact truncated: {} bytes, need at least {}",
            bytes.len(),
            HEADER_LEN + 4
        ));
    }
    if bytes[..4] != MAGIC {
        return corrupt("partial artifact: bad magic (want PDSP)");
    }
    let version = le_u32_at(bytes, 4);
    let kind = le_u32_at(bytes, 8);
    let len = le_u64_at(bytes, 12);
    let len: usize = match len.try_into() {
        Ok(l) => l,
        Err(_) => return corrupt(format!("partial artifact: payload length {len} overflows")),
    };
    let total = match HEADER_LEN.checked_add(len).and_then(|t| t.checked_add(4)) {
        Some(t) => t,
        None => return corrupt(format!("partial artifact: payload length {len} overflows")),
    };
    if bytes.len() < total {
        return corrupt(format!(
            "partial artifact truncated: {} bytes, header promises {total}",
            bytes.len()
        ));
    }
    if bytes.len() > total {
        return corrupt(format!(
            "partial artifact: {} trailing bytes after the checksum",
            bytes.len() - total
        ));
    }
    let body = &bytes[..HEADER_LEN + len];
    let stored = le_u32_at(bytes, HEADER_LEN + len);
    let computed = crc32(body);
    if stored != computed {
        return corrupt(format!(
            "partial artifact checksum mismatch: stored {stored:#010x}, computed {computed:#010x}"
        ));
    }
    Ok((version, kind, &bytes[HEADER_LEN..HEADER_LEN + len]))
}

/// Read just the kind tag of an artifact (CLI dispatch) — validates the
/// whole envelope, including the checksum.
pub fn peek_kind(bytes: &[u8]) -> Result<u32> {
    decode_artifact(bytes).map(|(_, kind, _)| kind)
}

/// Little-endian payload writer.
pub(crate) struct PayloadWriter {
    buf: Vec<u8>,
}

impl PayloadWriter {
    pub(crate) fn new() -> Self {
        PayloadWriter { buf: Vec::new() }
    }

    pub(crate) fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub(crate) fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn f64s(&mut self, vs: &[f64]) {
        for &v in vs {
            self.f64(v);
        }
    }

    pub(crate) fn u64s(&mut self, vs: &[u64]) {
        for &v in vs {
            self.u64(v);
        }
    }

    /// Length-prefixed nested blob (e.g. a child partial's payload).
    pub(crate) fn blob(&mut self, bytes: &[u8]) {
        self.u64(usize_to_u64(bytes.len()));
        self.buf.extend_from_slice(bytes);
    }

    pub(crate) fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Little-endian payload reader with typed truncation errors.
pub(crate) struct PayloadReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> PayloadReader<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        PayloadReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = match self.pos.checked_add(n) {
            Some(e) if e <= self.buf.len() => e,
            _ => {
                return corrupt(format!(
                    "partial payload truncated: need {n} bytes at offset {}, have {}",
                    self.pos,
                    self.buf.len() - self.pos
                ))
            }
        };
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        Ok(le_u32_at(self.take(4)?, 0))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        Ok(le_u64_at(self.take(8)?, 0))
    }

    pub(crate) fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(le_u64_at(self.take(8)?, 0)))
    }

    /// A `u64` that must fit in `usize` (lengths, dimensions).
    pub(crate) fn len(&mut self) -> Result<usize> {
        let v = self.u64()?;
        v.try_into().or_else(|_| corrupt(format!("partial payload: length {v} overflows")))
    }

    pub(crate) fn f64s(&mut self, n: usize) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(n.min(self.buf.len() / 8 + 1));
        for _ in 0..n {
            out.push(self.f64()?);
        }
        Ok(out)
    }

    pub(crate) fn u64s(&mut self, n: usize) -> Result<Vec<u64>> {
        let mut out = Vec::with_capacity(n.min(self.buf.len() / 8 + 1));
        for _ in 0..n {
            out.push(self.u64()?);
        }
        Ok(out)
    }

    /// Length-prefixed nested blob.
    pub(crate) fn blob(&mut self) -> Result<&'a [u8]> {
        let n = self.len()?;
        self.take(n)
    }

    /// Assert the payload was consumed exactly.
    pub(crate) fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            return corrupt(format!(
                "partial payload: {} trailing bytes",
                self.buf.len() - self.pos
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;

    #[test]
    fn envelope_round_trip() {
        let payload = b"hello partial".to_vec();
        let art = encode_artifact(5, 2, &payload);
        let (version, kind, body) = decode_artifact(&art).unwrap();
        assert_eq!((version, kind), (2, 5));
        assert_eq!(body, &payload[..]);
        assert_eq!(peek_kind(&art).unwrap(), 5);
    }

    #[test]
    fn every_truncation_is_corrupt_not_panic() {
        let art = encode_artifact(1, 1, &[7u8; 33]);
        for cut in 0..art.len() {
            match decode_artifact(&art[..cut]) {
                Err(Error::Corrupt(_)) => {}
                other => panic!("cut at {cut}: expected Corrupt, got {other:?}"),
            }
        }
    }

    #[test]
    fn bit_flips_are_corrupt() {
        let art = encode_artifact(1, 1, &[7u8; 33]);
        // flip one bit in every byte position; every damaged buffer must
        // fail typed (magic/length damage included — length damage either
        // truncates or leaves trailing bytes, both Corrupt)
        for pos in 0..art.len() {
            let mut bad = art.clone();
            bad[pos] ^= 0x10;
            match decode_artifact(&bad) {
                Err(Error::Corrupt(_)) => {}
                other => panic!("flip at {pos}: expected Corrupt, got {other:?}"),
            }
        }
    }

    #[test]
    fn trailing_bytes_are_corrupt() {
        let mut art = encode_artifact(1, 1, b"x");
        art.push(0);
        assert!(matches!(decode_artifact(&art), Err(Error::Corrupt(_))));
    }

    #[test]
    fn payload_reader_truncation_is_typed() {
        let mut w = PayloadWriter::new();
        w.u64(3);
        let bytes = w.finish();
        let mut r = PayloadReader::new(&bytes);
        assert_eq!(r.u64().unwrap(), 3);
        assert!(matches!(r.u64(), Err(Error::Corrupt(_))));
    }

    #[test]
    fn payload_reader_rejects_trailing() {
        let mut w = PayloadWriter::new();
        w.u32(1);
        w.u8(9);
        let bytes = w.finish();
        let mut r = PayloadReader::new(&bytes);
        assert_eq!(r.u32().unwrap(), 1);
        assert!(matches!(r.finish(), Err(Error::Corrupt(_))));
    }
}
