//! The Barger–Feldman merge-and-reduce coreset tree (arXiv:1511.08990)
//! as a mergeable [`PartialFit`] for streaming / distributed K-means.
//!
//! Each store shard becomes a **leaf**: its sparsified columns are
//! densified (rescaled by `p/m` under uniform schemes, by 1 under
//! weighted schemes, matching the estimator calibrations) and reduced to
//! at most `capacity` weighted points by lightweight-coreset importance
//! sampling — `q(x) = ½·w/W + ½·w·d²(x, μ)/Σ w d²`, sampled weight
//! `w/(t·q)` (Bachem et al.'s lightweight construction, the
//! sampling-based reduce step the merge-and-reduce scheme composes).
//!
//! Leaves live at `(level 0, index = shard)` in a dyadic tree over shard
//! indices; whenever both children `(h, 2j)` and `(h, 2j+1)` are
//! present, they reduce into `(h+1, j)` (binary-counter carry). The
//! reduction RNG is seeded from the produced node's `(level, index)`
//! key, so the surviving node set **and every node's contents** are a
//! function of the set of shards ingested — not of ingestion order,
//! merge order, or how the shards were partitioned across workers. That
//! is what makes the tree a lawful [`PartialFit`]: merge is a union of
//! disjoint-coverage node maps followed by deterministic carries.
//!
//! Memory is O(levels × capacity) points per partial, independent of
//! stream length — the bounded-memory property the paper's streaming
//! claim needs.

use std::collections::BTreeMap;

use super::artifact::{PayloadReader, PayloadWriter};
use super::{kind, PartialFit};
use crate::error::{corrupt, invalid, Result};
use crate::kmeans::KmeansOpts;
use crate::linalg::Mat;
use crate::rng::Pcg64;

/// Stream-salt for per-node reduction RNGs (mixed with the store seed).
const CORESET_SALT: u64 = 0x434F_5245;

/// Squared Euclidean distance.
fn dist2(a: &[f64], b: &[f64]) -> f64 {
    let mut s = 0.0;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

/// One tree node: a weighted point set (points are columns).
#[derive(Clone, Debug)]
struct CoresetNode {
    points: Mat,
    weights: Vec<f64>,
}

/// Lightweight-coreset reduction: importance-sample `t` weighted points
/// (with replacement) from a weighted point set.
fn lightweight_sample(points: &Mat, weights: &[f64], t: usize, rng: &mut Pcg64) -> CoresetNode {
    let (p, n) = (points.rows(), points.cols());
    debug_assert!(n > t && n == weights.len());
    let w_total: f64 = weights.iter().sum();
    let mut mu = vec![0.0; p];
    for j in 0..n {
        let c = points.col(j);
        for i in 0..p {
            mu[i] += weights[j] * c[i];
        }
    }
    for v in &mut mu {
        *v /= w_total;
    }
    let d2: Vec<f64> = (0..n).map(|j| dist2(points.col(j), &mu)).collect();
    let spread: f64 = weights.iter().zip(&d2).map(|(w, d)| w * d).sum();
    // q(x_j) — if every point sits on the mean, fall back to pure
    // weight-proportional sampling
    let q: Vec<f64> = (0..n)
        .map(|j| {
            let tail =
                if spread > 0.0 { 0.5 * weights[j] * d2[j] / spread } else { 0.5 * weights[j] / w_total };
            0.5 * weights[j] / w_total + tail
        })
        .collect();
    let mut cum = Vec::with_capacity(n);
    let mut acc = 0.0;
    for &qj in &q {
        acc += qj;
        cum.push(acc);
    }
    let total = acc;
    let mut out = Mat::zeros(p, t);
    let mut w_out = vec![0.0; t];
    for s in 0..t {
        let u = rng.next_f64() * total;
        let j = cum.partition_point(|&c| c < u).min(n - 1);
        out.col_mut(s).copy_from_slice(points.col(j));
        w_out[s] = weights[j] / (t as f64 * q[j]);
    }
    CoresetNode { points: out, weights: w_out }
}

/// Merge-and-reduce coreset tree over store shards — see the [module
/// docs](self).
#[derive(Clone, Debug)]
pub struct CoresetPartial {
    p: usize,
    /// Maximum points per node (the coreset size `t`).
    capacity: usize,
    /// Base seed (mix of the fit seed; per-node streams derive from it).
    seed: u64,
    /// Nodes keyed `(level, index)`; node `(h, i)` summarizes shards
    /// `[i·2^h, (i+1)·2^h)`.
    nodes: BTreeMap<(u32, u64), CoresetNode>,
}

/// The dyadic shard range `[lo, hi)` a node key covers. Callers keep
/// `h` small enough that the shift cannot overflow (decode enforces it
/// for untrusted input).
fn node_range(key: (u32, u64)) -> (u64, u64) {
    let (h, i) = key;
    (i << h, (i + 1) << h)
}

/// Half-open interval overlap.
fn ranges_overlap(a: (u64, u64), b: (u64, u64)) -> bool {
    a.0 < b.1 && b.0 < a.1
}

impl CoresetPartial {
    /// Empty tree for dimension `p`, node capacity `capacity`, fit seed
    /// `seed` (all three are part of the partial's identity: partials
    /// built with different parameters refuse to merge).
    pub fn new(p: usize, capacity: usize, seed: u64) -> Result<Self> {
        if capacity < 2 {
            return invalid(format!("coreset capacity must be >= 2, got {capacity}"));
        }
        Ok(CoresetPartial { p, capacity, seed, nodes: BTreeMap::new() })
    }

    /// Node capacity `t`.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    fn node_rng(&self, key: (u32, u64)) -> Pcg64 {
        let (h, i) = key;
        Pcg64::seed_stream(self.seed ^ CORESET_SALT, ((h as u64) << 32) ^ i)
    }

    fn reduced(&self, key: (u32, u64), node: CoresetNode) -> CoresetNode {
        if node.points.cols() <= self.capacity {
            return node;
        }
        let mut rng = self.node_rng(key);
        lightweight_sample(&node.points, &node.weights, self.capacity, &mut rng)
    }

    /// Whether `shard` is already summarized by some node.
    fn covers(&self, shard: u64) -> bool {
        self.nodes.keys().any(|&k| {
            let (lo, hi) = node_range(k);
            (lo..hi).contains(&shard)
        })
    }

    /// Whether any node's range overlaps `range`.
    fn covers_range(&self, range: (u64, u64)) -> bool {
        self.nodes.keys().any(|&k| ranges_overlap(node_range(k), range))
    }

    /// Ingest one shard's densified columns as leaf `(0, shard)` with
    /// unit weights, then carry-propagate sibling reductions.
    pub fn add_leaf(&mut self, shard: u64, points: Mat, weights: Vec<f64>) -> Result<()> {
        if points.rows() != self.p {
            return invalid(format!(
                "coreset leaf p={} does not match partial p={}",
                points.rows(),
                self.p
            ));
        }
        if points.cols() != weights.len() || points.cols() == 0 {
            return invalid(format!(
                "coreset leaf: {} points with {} weights",
                points.cols(),
                weights.len()
            ));
        }
        if self.covers(shard) {
            return invalid(format!("coreset: shard {shard} ingested twice"));
        }
        let leaf = self.reduced((0, shard), CoresetNode { points, weights });
        self.nodes.insert((0, shard), leaf);
        self.carry();
        Ok(())
    }

    /// Reduce every complete sibling pair bottom-up until none remain.
    /// Confluent: node contents depend only on the leaf set, so the scan
    /// order cannot matter.
    fn carry(&mut self) {
        loop {
            let pair = self
                .nodes
                .keys()
                .find(|&&(h, i)| i % 2 == 0 && self.nodes.contains_key(&(h, i + 1)))
                .copied();
            let Some((h, i)) = pair else { break };
            let (Some(left), Some(right)) =
                (self.nodes.remove(&(h, i)), self.nodes.remove(&(h, i + 1)))
            else {
                // unreachable: both keys were found by the scan above;
                // stop carrying rather than panic if that ever changes
                break;
            };
            let parent = (h + 1, i / 2);
            let mut points = Mat::zeros(self.p, left.points.cols() + right.points.cols());
            let mut weights = Vec::with_capacity(left.weights.len() + right.weights.len());
            let mut col = 0;
            for node in [&left, &right] {
                for j in 0..node.points.cols() {
                    points.col_mut(col).copy_from_slice(node.points.col(j));
                    col += 1;
                }
                weights.extend_from_slice(&node.weights);
            }
            let merged = self.reduced(parent, CoresetNode { points, weights });
            self.nodes.insert(parent, merged);
        }
    }

    /// Sorted dyadic shard ranges `[lo, hi)` the tree currently covers.
    pub fn coverage(&self) -> Vec<(u64, u64)> {
        let mut ranges: Vec<(u64, u64)> = self.nodes.keys().map(|&k| node_range(k)).collect();
        ranges.sort_unstable();
        ranges
    }

    /// Whether the tree covers exactly shards `0..shard_count`.
    pub fn covers_exactly(&self, shard_count: u64) -> bool {
        let mut next = 0;
        for (lo, hi) in self.coverage() {
            if lo != next {
                return false;
            }
            next = hi;
        }
        next == shard_count
    }

    /// Concatenate the surviving nodes (in key order) into one weighted
    /// point set — the coreset handed to the final weighted K-means.
    pub fn points(&self) -> (Mat, Vec<f64>) {
        let total: usize = self.nodes.values().map(|n| n.points.cols()).sum();
        let mut points = Mat::zeros(self.p, total);
        let mut weights = Vec::with_capacity(total);
        let mut col = 0;
        for node in self.nodes.values() {
            for j in 0..node.points.cols() {
                points.col_mut(col).copy_from_slice(node.points.col(j));
                col += 1;
            }
            weights.extend_from_slice(&node.weights);
        }
        (points, weights)
    }
}

impl PartialFit for CoresetPartial {
    const KIND: u32 = kind::CORESET;
    const VERSION: u32 = 1;

    fn kind_name() -> &'static str {
        "coreset"
    }

    fn identity_like(&self) -> Self {
        CoresetPartial { p: self.p, capacity: self.capacity, seed: self.seed, nodes: BTreeMap::new() }
    }

    fn merge_from(&mut self, other: &Self) -> Result<()> {
        if (self.p, self.capacity, self.seed) != (other.p, other.capacity, other.seed) {
            return invalid(format!(
                "cannot merge coreset partial (p={}, capacity={}, seed={}) with (p={}, \
                 capacity={}, seed={})",
                self.p, self.capacity, self.seed, other.p, other.capacity, other.seed
            ));
        }
        for &key in other.nodes.keys() {
            let (lo, hi) = node_range(key);
            if self.covers_range((lo, hi)) {
                return invalid(format!(
                    "coreset: shard range [{lo}, {hi}) present in both partials"
                ));
            }
        }
        for (&key, node) in &other.nodes {
            self.nodes.insert(key, node.clone());
        }
        self.carry();
        Ok(())
    }

    fn encode_payload(&self) -> Vec<u8> {
        let mut w = PayloadWriter::new();
        w.u64(self.p as u64);
        w.u64(self.capacity as u64);
        w.u64(self.seed);
        w.u64(self.nodes.len() as u64);
        for (&(h, i), node) in &self.nodes {
            w.u32(h);
            w.u64(i);
            w.u64(node.points.cols() as u64);
            w.f64s(&node.weights);
            w.f64s(node.points.as_slice());
        }
        w.finish()
    }

    fn decode_payload(_version: u32, payload: &[u8]) -> Result<Self> {
        let mut r = PayloadReader::new(payload);
        let p = r.len()?;
        let capacity = r.len()?;
        let seed = r.u64()?;
        let count = r.len()?;
        if capacity < 2 {
            return corrupt(format!("coreset partial: capacity {capacity} < 2"));
        }
        let mut out = CoresetPartial { p, capacity, seed, nodes: BTreeMap::new() };
        for _ in 0..count {
            let h = r.u32()?;
            let i = r.u64()?;
            // bound the dyadic range so node_range's shifts cannot
            // overflow on hostile input (2^62 shards is far beyond any
            // real store)
            if h >= 63 || i >= (1u64 << (63 - h)) {
                return corrupt(format!("coreset partial: node ({h}, {i}) range overflows"));
            }
            let n = r.len()?;
            if n == 0 || n > capacity {
                return corrupt(format!(
                    "coreset partial: node ({h}, {i}) holds {n} points (capacity {capacity})"
                ));
            }
            let weights = r.f64s(n)?;
            let cells = p
                .checked_mul(n)
                .ok_or(())
                .or_else(|_| corrupt(format!("coreset partial: p*n overflows ({p}*{n})")))?;
            let points = Mat::from_vec(p, n, r.f64s(cells)?)?;
            if out.covers_range(node_range((h, i))) {
                return corrupt(format!(
                    "coreset partial: node ({h}, {i}) overlaps earlier coverage"
                ));
            }
            out.nodes.insert((h, i), CoresetNode { points, weights });
        }
        r.finish()?;
        Ok(out)
    }
}

/// Weighted K-means on a dense weighted point set (the coreset):
/// weighted k-means++ seeding + weighted Lloyd, `opts.n_init` restarts
/// with the same per-start seed streams as the sparsified fit. Returns
/// `(centers, iterations, converged)` of the best restart by weighted
/// objective.
pub fn weighted_kmeans(
    points: &Mat,
    weights: &[f64],
    k: usize,
    opts: &KmeansOpts,
) -> Result<(Mat, usize, bool)> {
    let (p, n) = (points.rows(), points.cols());
    if n != weights.len() {
        return invalid(format!("weighted_kmeans: {n} points with {} weights", weights.len()));
    }
    if k == 0 || k > n {
        return invalid(format!("weighted_kmeans: k={k} with {n} points"));
    }
    let mut best: Option<(f64, Mat, usize, bool)> = None;
    for start in 0..opts.n_init.max(1) {
        let mut rng = Pcg64::seed_stream(opts.seed, 0xC0DE ^ crate::convert::usize_to_u64(start));
        let centers = weighted_pp(points, weights, k, &mut rng);
        let (centers, obj, iters, converged) = weighted_lloyd(points, weights, centers, opts);
        let better = match &best {
            Some((b, ..)) => obj < *b,
            None => true,
        };
        if better {
            best = Some((obj, centers, iters, converged));
        }
    }
    let Some((_, centers, iters, converged)) = best else {
        // unreachable: the loop above runs max(n_init, 1) >= 1 times
        return invalid("weighted_kmeans: no restart produced a solution".to_string());
    };
    debug_assert_eq!(centers.rows(), p);
    Ok((centers, iters, converged))
}

/// Weighted k-means++: first center drawn ∝ weight, subsequent centers
/// ∝ weight × squared distance to the nearest chosen center.
fn weighted_pp(points: &Mat, weights: &[f64], k: usize, rng: &mut Pcg64) -> Mat {
    let (p, n) = (points.rows(), points.cols());
    let mut centers = Mat::zeros(p, k);
    let draw = |mass: &[f64], rng: &mut Pcg64| -> usize {
        let total: f64 = mass.iter().sum();
        if total <= 0.0 {
            return (rng.next_u64() % n as u64) as usize;
        }
        let u = rng.next_f64() * total;
        let mut acc = 0.0;
        for (j, &m) in mass.iter().enumerate() {
            acc += m;
            if u < acc {
                return j;
            }
        }
        n - 1
    };
    let first = draw(weights, rng);
    centers.col_mut(0).copy_from_slice(points.col(first));
    let mut d2: Vec<f64> = (0..n).map(|j| dist2(points.col(j), centers.col(0))).collect();
    for c in 1..k {
        let mass: Vec<f64> = d2.iter().zip(weights).map(|(d, w)| d * w).collect();
        let pick = draw(&mass, rng);
        centers.col_mut(c).copy_from_slice(points.col(pick));
        for j in 0..n {
            let d = dist2(points.col(j), centers.col(c));
            if d < d2[j] {
                d2[j] = d;
            }
        }
    }
    centers
}

/// Weighted Lloyd iterations until assignments stabilize (≤ `tol_frac·n`
/// changes) or `max_iters`. Empty clusters keep their previous center.
fn weighted_lloyd(
    points: &Mat,
    weights: &[f64],
    mut centers: Mat,
    opts: &KmeansOpts,
) -> (Mat, f64, usize, bool) {
    let (p, n) = (points.rows(), points.cols());
    let k = centers.cols();
    let mut assign = vec![u32::MAX; n];
    let mut objective = 0.0;
    let mut converged = false;
    let mut iters = 0;
    let tol = (opts.tol_frac * n as f64) as usize;
    for _ in 0..opts.max_iters.max(1) {
        iters += 1;
        let mut changed = 0usize;
        objective = 0.0;
        for j in 0..n {
            let x = points.col(j);
            let mut best_c = 0u32;
            let mut best_d = f64::INFINITY;
            for c in 0..k {
                let d = dist2(x, centers.col(c));
                if d < best_d {
                    best_d = d;
                    best_c = c as u32;
                }
            }
            if assign[j] != best_c {
                changed += 1;
                assign[j] = best_c;
            }
            objective += weights[j] * best_d;
        }
        if changed <= tol {
            converged = true;
            break;
        }
        let mut sums = Mat::zeros(p, k);
        let mut mass = vec![0.0f64; k];
        for j in 0..n {
            let c = assign[j] as usize;
            let x = points.col(j);
            let s = sums.col_mut(c);
            for i in 0..p {
                s[i] += weights[j] * x[i];
            }
            mass[c] += weights[j];
        }
        for c in 0..k {
            if mass[c] > 0.0 {
                let s = sums.col(c).to_vec();
                let dst = centers.col_mut(c);
                for i in 0..p {
                    dst[i] = s[i] / mass[c];
                }
            }
        }
    }
    (centers, objective, iters, converged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian_blobs;
    use crate::error::Error;
    use crate::testing::prop::assert_mergeable;

    fn leaf_points(p: usize, n: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Pcg64::seed(seed);
        (Mat::from_fn(p, n, |_, _| rng.normal()), vec![1.0; n])
    }

    fn tree_with_shards(shards: &[u64]) -> CoresetPartial {
        let mut t = CoresetPartial::new(8, 16, 99).unwrap();
        for &s in shards {
            let (pts, w) = leaf_points(8, 24, 1000 + s);
            t.add_leaf(s, pts, w).unwrap();
        }
        t
    }

    fn bits_eq(a: &CoresetPartial, b: &CoresetPartial) -> bool {
        if a.nodes.keys().collect::<Vec<_>>() != b.nodes.keys().collect::<Vec<_>>() {
            return false;
        }
        a.nodes.values().zip(b.nodes.values()).all(|(x, y)| {
            x.weights.iter().zip(&y.weights).all(|(u, v)| u.to_bits() == v.to_bits())
                && x.points
                    .as_slice()
                    .iter()
                    .zip(y.points.as_slice())
                    .all(|(u, v)| u.to_bits() == v.to_bits())
        })
    }

    #[test]
    fn merge_laws_bitwise() {
        // one partial per shard; the checker permutes and partitions the
        // merges — carries fire in all sorts of interleavings, and the
        // per-node seed streams must make the outcome bitwise identical
        let items: Vec<CoresetPartial> = (0..6).map(|s| tree_with_shards(&[s])).collect();
        assert_mergeable(
            "coreset_merge",
            &items,
            || CoresetPartial::new(8, 16, 99).unwrap(),
            |a, b| a.merge_from(b).unwrap(),
            bits_eq,
        );
    }

    #[test]
    fn ingestion_order_is_irrelevant() {
        // same shard set, built leaf-by-leaf in different orders
        let a = tree_with_shards(&[0, 1, 2, 3, 4]);
        let b = tree_with_shards(&[4, 2, 0, 3, 1]);
        assert!(bits_eq(&a, &b));
        // 5 leaves → binary 101: one node at level 2, one at level 0
        assert_eq!(a.nodes.keys().copied().collect::<Vec<_>>(), vec![(0, 4), (2, 0)]);
        assert!(a.covers_exactly(5));
        assert!(!a.covers_exactly(6));
    }

    #[test]
    fn memory_stays_bounded() {
        let t = tree_with_shards(&(0..32).collect::<Vec<_>>());
        // 32 = 2^5 shards collapse to a single root node of ≤ capacity
        assert_eq!(t.nodes.len(), 1);
        let (pts, w) = t.points();
        assert!(pts.cols() <= t.capacity());
        assert_eq!(pts.cols(), w.len());
        assert!(t.covers_exactly(32));
    }

    #[test]
    fn weights_preserve_total_mass_approximately() {
        // Σ sampled weights has expectation Σ original weights (n per
        // leaf, unit weights); check it is in the right ballpark
        let t = tree_with_shards(&[0, 1, 2, 3]);
        let (_, w) = t.points();
        let total: f64 = w.iter().sum();
        let expect = 4.0 * 24.0;
        assert!(
            total > 0.4 * expect && total < 2.5 * expect,
            "mass {total} vs ingested {expect}"
        );
    }

    #[test]
    fn duplicate_shard_is_invalid() {
        let mut t = tree_with_shards(&[0, 1]);
        let (pts, w) = leaf_points(8, 10, 5);
        // shard 1 is covered by the (1, 0) parent now — still refused
        match t.add_leaf(1, pts, w) {
            Err(Error::Invalid(msg)) => assert!(msg.contains("twice"), "{msg}"),
            other => panic!("expected Invalid, got {other:?}"),
        }
    }

    #[test]
    fn mismatched_configs_refuse_to_merge() {
        let mut a = tree_with_shards(&[0]);
        let b = {
            let mut t = CoresetPartial::new(8, 16, 100).unwrap(); // different seed
            let (pts, w) = leaf_points(8, 24, 7);
            t.add_leaf(1, pts, w).unwrap();
            t
        };
        assert!(matches!(a.merge_from(&b), Err(Error::Invalid(_))));
    }

    #[test]
    fn serialization_round_trip() {
        let t = tree_with_shards(&[0, 1, 2]);
        let back = CoresetPartial::from_bytes(&t.to_bytes()).unwrap();
        assert!(bits_eq(&t, &back));
        assert_eq!(back.capacity(), t.capacity());
    }

    #[test]
    fn overfull_node_is_corrupt() {
        // capacity says 16 but a node claims more points
        let t = tree_with_shards(&[0]);
        let mut payload_patch = t.encode_payload();
        // capacity field is bytes [8, 16) of the payload — shrink it so
        // the node's point count exceeds it
        payload_patch[8..16].copy_from_slice(&2u64.to_le_bytes());
        let art = super::super::encode_artifact(
            CoresetPartial::KIND,
            CoresetPartial::VERSION,
            &payload_patch,
        );
        match CoresetPartial::from_bytes(&art) {
            Err(Error::Corrupt(_)) => {}
            other => panic!("expected Corrupt, got {other:?}"),
        }
    }

    #[test]
    fn weighted_kmeans_recovers_blobs() {
        let mut rng = Pcg64::seed(3);
        let d = gaussian_blobs(6, 300, 3, 0.05, &mut rng);
        let w = vec![1.0; 300];
        let (centers, _, converged) =
            weighted_kmeans(&d.data, &w, 3, &KmeansOpts { n_init: 4, ..Default::default() })
                .unwrap();
        assert!(converged);
        // every sample should sit close to some center
        for j in 0..300 {
            let best = (0..3)
                .map(|c| dist2(d.data.col(j), centers.col(c)))
                .fold(f64::INFINITY, f64::min);
            assert!(best < 1.0, "sample {j} far from all centers: {best}");
        }
    }

    #[test]
    fn weighted_kmeans_respects_weights() {
        // two points, all the mass on one of them, k=1 → center ≈ heavy point
        let mut pts = Mat::zeros(2, 2);
        pts.col_mut(0).copy_from_slice(&[0.0, 0.0]);
        pts.col_mut(1).copy_from_slice(&[10.0, 10.0]);
        let (centers, _, _) = weighted_kmeans(
            &pts,
            &[1e-9, 1.0],
            1,
            &KmeansOpts { max_iters: 50, ..Default::default() },
        )
        .unwrap();
        assert!((centers.get(0, 0) - 10.0).abs() < 1e-6);
    }

    #[test]
    fn weighted_kmeans_rejects_bad_args() {
        let (pts, w) = leaf_points(4, 10, 1);
        assert!(matches!(
            weighted_kmeans(&pts, &w[..5], 2, &KmeansOpts::default()),
            Err(Error::Invalid(_))
        ));
        assert!(matches!(
            weighted_kmeans(&pts, &w, 11, &KmeansOpts::default()),
            Err(Error::Invalid(_))
        ));
    }
}
