//! Distributed fit: serializable, lawfully mergeable partial-fit state.
//!
//! The paper's headline claim is that sparsified data makes PCA and
//! K-means cheap *"especially in a distributed-data setting"*: every
//! estimator in this crate is a streaming fold, so N workers can each
//! fold their own disjoint shard range of a
//! [`SparseStoreReader`](crate::store::SparseStoreReader) and a
//! coordinator can combine the partial states. This module makes those
//! partials first-class: the [`PartialFit`] trait gives each one an
//! identity element, a **checked** merge, and a versioned, checksummed
//! byte encoding (the `.pdsp` artifact, specified in `docs/FORMAT.md`).
//!
//! ## Merge laws
//!
//! Every implementation satisfies, and is property-tested for
//! (`testing::prop::assert_mergeable`):
//!
//! 1. **identity** — `identity_like() ⊕ x == x == x ⊕ identity_like()`;
//! 2. **order invariance** — folding a set of partials yields the same
//!    result under every permutation;
//! 3. **partition invariance** — pre-merging any chunking of the set,
//!    then merging the chunk results, equals the flat fold.
//!
//! For the f64 estimators these laws hold **bitwise**, not just
//! approximately: a partial keeps its accumulated state *per shard*
//! (keyed by shard index) and merge is a disjoint map union, so the
//! float additions happen only at finalize time, always in shard-index
//! order — no merge order or partition can re-associate them. The
//! partitioned fit's bit-identity reference is therefore the
//! single-process partitioned fit (`FitPlan::partition(1)`), which runs
//! the identical per-shard fold; the legacy unpartitioned drivers fold
//! sample-by-sample across shard boundaries, which is the same sum in a
//! different association (equal to f64 rounding, not to the bit).
//!
//! ## The coreset partial
//!
//! [`CoresetPartial`] implements the merge-and-reduce coreset tree of
//! Barger & Feldman, *k-Means for Streaming and Distributed Big Sparse
//! Data* (arXiv:1511.08990): each shard becomes a weighted leaf coreset,
//! siblings in a dyadic tree over shard indices reduce bottom-up, and the
//! per-node reduction RNG is derived from the node's `(level, index)`
//! key — so the surviving tree is a function of the *set* of shards
//! ingested, not of the merge schedule. Bounded memory (O(levels ×
//! capacity) points) for unbounded streams, behind
//! `FitPlan::kmeans().solver(Solver::Coreset)`.

mod artifact;
mod coreset;
mod partials;

pub use artifact::{decode_artifact, encode_artifact, peek_kind};
pub(crate) use artifact::{PayloadReader, PayloadWriter};
pub use coreset::{weighted_kmeans, CoresetPartial};
pub use partials::{CenterPartial, CenterUpdate, PcaPartial};

use crate::error::{invalid, Result};

/// Mergeable, serializable partial-fit state — see the [module
/// docs](self) for the laws every implementation upholds.
pub trait PartialFit: Clone + Sized {
    /// Stable artifact kind tag recorded in the `.pdsp` envelope.
    const KIND: u32;
    /// Payload format version this build writes (per kind).
    const VERSION: u32;

    /// Human-readable kind name for error messages.
    fn kind_name() -> &'static str;

    /// A fresh identity partial carrying this partial's shape/config
    /// (merging it into anything is a no-op, and anything merges into it
    /// unchanged).
    fn identity_like(&self) -> Self;

    /// Fold `other` into `self`. Checked: shape/config mismatches and
    /// overlapping shard coverage return
    /// [`Error::Invalid`](crate::error::Error::Invalid) instead of
    /// silently mixing incompatible state.
    fn merge_from(&mut self, other: &Self) -> Result<()>;

    /// Encode the payload (everything inside the envelope).
    fn encode_payload(&self) -> Vec<u8>;

    /// Decode a payload written by format `version` (≤ [`VERSION`](Self::VERSION)).
    fn decode_payload(version: u32, payload: &[u8]) -> Result<Self>;

    /// Serialize into a `.pdsp` artifact (envelope + payload + CRC).
    fn to_bytes(&self) -> Vec<u8> {
        artifact::encode_artifact(Self::KIND, Self::VERSION, &self.encode_payload())
    }

    /// Deserialize a `.pdsp` artifact. Truncation, tampering, and
    /// trailing bytes are [`Error::Corrupt`](crate::error::Error::Corrupt);
    /// a foreign kind or a version newer than this build is
    /// [`Error::Invalid`](crate::error::Error::Invalid).
    fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let (version, kind, payload) = artifact::decode_artifact(bytes)?;
        if kind != Self::KIND {
            return invalid(format!(
                "partial artifact kind {kind} is not a {} partial (kind {})",
                Self::kind_name(),
                Self::KIND
            ));
        }
        if version > Self::VERSION {
            return invalid(format!(
                "{} partial version {version} is newer than this build's {}",
                Self::kind_name(),
                Self::VERSION
            ));
        }
        Self::decode_payload(version, payload)
    }
}

/// Artifact kind tags (the `kind` field of the `.pdsp` envelope).
pub mod kind {
    /// [`SparseMeanEstimator`](crate::estimators::SparseMeanEstimator).
    pub const MEAN: u32 = 1;
    /// [`CovarianceEstimator`](crate::estimators::CovarianceEstimator).
    pub const COVARIANCE: u32 = 2;
    /// [`HkAccumulator`](crate::estimators::HkAccumulator).
    pub const HK: u32 = 3;
    /// [`CenterPartial`](super::CenterPartial) (one Lloyd iteration).
    pub const CENTER: u32 = 4;
    /// [`PcaPartial`](super::PcaPartial) (per-shard mean + covariance).
    pub const PCA: u32 = 5;
    /// [`CoresetPartial`](super::CoresetPartial) (merge-and-reduce tree).
    pub const CORESET: u32 = 6;
    /// [`ModelSnapshot`](crate::serve::snapshot::ModelSnapshot) — the
    /// serve daemon's persisted warm-start model.
    pub const SNAPSHOT: u32 = 7;
}
