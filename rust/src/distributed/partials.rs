//! [`PartialFit`] implementations: the estimator accumulators, the
//! per-shard PCA partial, and the per-shard Lloyd-iteration partial.
//!
//! The estimator impls (`SparseMeanEstimator`, `CovarianceEstimator`,
//! `HkAccumulator`) serialize and merge the accumulators directly —
//! exact for the integer-count HK fold, order-invariant to f64
//! re-association for the float sums. The composite partials
//! ([`PcaPartial`], [`CenterPartial`]) keep their state **per shard**
//! and merge by disjoint map union, so they are *bitwise*
//! order/partition-invariant: the float folds happen only at finalize,
//! always in shard-index order.

use std::collections::BTreeMap;

use super::artifact::{PayloadReader, PayloadWriter};
use super::{kind, PartialFit};
use crate::error::{corrupt, invalid, Result};
use crate::estimators::{CovarianceEstimator, HkAccumulator, SparseMeanEstimator};
use crate::kmeans::{solve_centers, CenterStep};
use crate::linalg::Mat;
use crate::sparse::SparseChunk;

impl PartialFit for SparseMeanEstimator {
    const KIND: u32 = kind::MEAN;
    const VERSION: u32 = 1;

    fn kind_name() -> &'static str {
        "mean"
    }

    fn identity_like(&self) -> Self {
        let (p, m) = self.shape();
        match self.scale_opt() {
            Some(s) => SparseMeanEstimator::new(p, m).with_scale(s),
            None => SparseMeanEstimator::new(p, m),
        }
    }

    fn merge_from(&mut self, other: &Self) -> Result<()> {
        if self.shape() != other.shape() || self.scale_opt() != other.scale_opt() {
            return invalid(format!(
                "cannot merge mean partial (p,m)={:?} scale={:?} with (p,m)={:?} scale={:?}",
                self.shape(),
                self.scale_opt(),
                other.shape(),
                other.scale_opt()
            ));
        }
        self.merge(other);
        Ok(())
    }

    fn encode_payload(&self) -> Vec<u8> {
        let (p, m) = self.shape();
        let mut w = PayloadWriter::new();
        w.u64(p as u64);
        w.u64(m as u64);
        w.u64(self.n() as u64);
        match self.scale_opt() {
            Some(s) => {
                w.u8(1);
                w.f64(s);
            }
            None => w.u8(0),
        }
        w.f64s(self.sum_raw());
        w.finish()
    }

    fn decode_payload(_version: u32, payload: &[u8]) -> Result<Self> {
        let mut r = PayloadReader::new(payload);
        let p = r.len()?;
        let m = r.len()?;
        let n = r.len()?;
        let scale = match r.u8()? {
            0 => None,
            1 => Some(r.f64()?),
            other => return corrupt(format!("mean partial: bad scale flag {other}")),
        };
        let sum = r.f64s(p)?;
        r.finish()?;
        Ok(SparseMeanEstimator::from_raw(p, m, scale, sum, n))
    }
}

impl PartialFit for CovarianceEstimator {
    const KIND: u32 = kind::COVARIANCE;
    const VERSION: u32 = 1;

    fn kind_name() -> &'static str {
        "covariance"
    }

    fn identity_like(&self) -> Self {
        let (p, m) = self.shape();
        if self.is_weighted() {
            CovarianceEstimator::new_weighted(p, m)
        } else {
            CovarianceEstimator::new(p, m)
        }
    }

    fn merge_from(&mut self, other: &Self) -> Result<()> {
        if self.shape() != other.shape() || self.is_weighted() != other.is_weighted() {
            return invalid(format!(
                "cannot merge covariance partial (p,m)={:?} weighted={} with (p,m)={:?} \
                 weighted={}",
                self.shape(),
                self.is_weighted(),
                other.shape(),
                other.is_weighted()
            ));
        }
        self.merge(other);
        Ok(())
    }

    fn encode_payload(&self) -> Vec<u8> {
        let (p, m) = self.shape();
        let mut w = PayloadWriter::new();
        w.u64(p as u64);
        w.u64(m as u64);
        w.u64(self.n() as u64);
        w.u8(self.is_weighted() as u8);
        w.f64s(self.acc_raw().as_slice());
        w.f64s(self.slot_diag_raw());
        w.finish()
    }

    fn decode_payload(_version: u32, payload: &[u8]) -> Result<Self> {
        let mut r = PayloadReader::new(payload);
        let p = r.len()?;
        let m = r.len()?;
        let n = r.len()?;
        let weighted = match r.u8()? {
            0 => false,
            1 => true,
            other => return corrupt(format!("covariance partial: bad weighted flag {other}")),
        };
        if m < 2 {
            return corrupt(format!("covariance partial: m={m} < 2"));
        }
        let acc_len = p.checked_mul(p).ok_or(())
            .or_else(|_| corrupt(format!("covariance partial: p={p} overflows p*p")))?;
        let acc = Mat::from_vec(p, p, r.f64s(acc_len)?)?;
        let slot_diag = r.f64s(if weighted { p } else { 0 })?;
        r.finish()?;
        Ok(CovarianceEstimator::from_raw(p, m, weighted, acc, slot_diag, n))
    }
}

impl PartialFit for HkAccumulator {
    const KIND: u32 = kind::HK;
    const VERSION: u32 = 1;

    fn kind_name() -> &'static str {
        "hk"
    }

    fn identity_like(&self) -> Self {
        let (p, m) = self.shape();
        HkAccumulator::new(p, m)
    }

    fn merge_from(&mut self, other: &Self) -> Result<()> {
        self.merge(other)
    }

    fn encode_payload(&self) -> Vec<u8> {
        let (p, m) = self.shape();
        let mut w = PayloadWriter::new();
        w.u64(p as u64);
        w.u64(m as u64);
        w.u64(self.n() as u64);
        w.u64s(self.counts_raw());
        w.finish()
    }

    fn decode_payload(_version: u32, payload: &[u8]) -> Result<Self> {
        let mut r = PayloadReader::new(payload);
        let p = r.len()?;
        let m = r.len()?;
        let n = r.len()?;
        let counts = r.u64s(p)?;
        r.finish()?;
        Ok(HkAccumulator::from_raw(p, m, counts, n))
    }
}

/// One worker's PCA partial: an independent `(mean, covariance)`
/// accumulator pair **per shard** of a sparse store. Merging is a
/// disjoint union of the shard maps — any merge order and any partition
/// of the shard set produce the same map, so
/// [`finalize`](Self::finalize) (which folds the per-shard states in
/// shard-index order) is bitwise reproducible.
#[derive(Clone, Debug)]
pub struct PcaPartial {
    p: usize,
    m: usize,
    /// Weighted-scheme calibration: mean scale 1.0 + cross-slot
    /// covariance instead of the uniform `p/m` rescales.
    weighted: bool,
    nodes: BTreeMap<u32, (SparseMeanEstimator, CovarianceEstimator)>,
}

impl PcaPartial {
    /// Empty partial for chunks of shape `(p, m)`; `weighted` selects the
    /// scheme calibration (matching `Sparsifier::weighted()`).
    pub fn new(p: usize, m: usize, weighted: bool) -> Self {
        PcaPartial { p, m, weighted, nodes: BTreeMap::new() }
    }

    fn fresh_node(&self) -> (SparseMeanEstimator, CovarianceEstimator) {
        if self.weighted {
            (
                SparseMeanEstimator::new(self.p, self.m).with_scale(1.0),
                CovarianceEstimator::new_weighted(self.p, self.m),
            )
        } else {
            (SparseMeanEstimator::new(self.p, self.m), CovarianceEstimator::new(self.p, self.m))
        }
    }

    /// Fold one chunk of shard `shard` into that shard's accumulators.
    pub fn fold_chunk(&mut self, shard: u32, chunk: &SparseChunk) -> Result<()> {
        if chunk.p() != self.p || chunk.m() != self.m {
            return invalid(format!(
                "pca partial: chunk (p,m)=({},{}) does not match partial ({},{})",
                chunk.p(),
                chunk.m(),
                self.p,
                self.m
            ));
        }
        let node = match self.nodes.entry(shard) {
            std::collections::btree_map::Entry::Occupied(e) => e.into_mut(),
            std::collections::btree_map::Entry::Vacant(e) => {
                let fresh = self.fresh_node();
                e.insert(fresh)
            }
        };
        node.0.accumulate(chunk);
        node.1.accumulate(chunk);
        Ok(())
    }

    /// Shard indices this partial covers (ascending).
    pub fn shards(&self) -> Vec<u32> {
        self.nodes.keys().copied().collect()
    }

    /// Samples accumulated across all shards.
    pub fn n(&self) -> usize {
        self.nodes.values().map(|(mean, _)| mean.n()).sum()
    }

    /// Fold the per-shard states in shard-index order into one
    /// `(mean, covariance)` estimator pair. Fails on an empty partial.
    pub fn finalize(&self) -> Result<(SparseMeanEstimator, CovarianceEstimator)> {
        if self.nodes.is_empty() {
            return invalid("pca partial: nothing to finalize (no shards folded)");
        }
        let (mut mean, mut cov) = self.fresh_node();
        for node in self.nodes.values() {
            mean.merge(&node.0);
            cov.merge(&node.1);
        }
        Ok((mean, cov))
    }
}

impl PartialFit for PcaPartial {
    const KIND: u32 = kind::PCA;
    const VERSION: u32 = 1;

    fn kind_name() -> &'static str {
        "pca"
    }

    fn identity_like(&self) -> Self {
        PcaPartial::new(self.p, self.m, self.weighted)
    }

    fn merge_from(&mut self, other: &Self) -> Result<()> {
        if (self.p, self.m, self.weighted) != (other.p, other.m, other.weighted) {
            return invalid(format!(
                "cannot merge pca partial (p={}, m={}, weighted={}) with (p={}, m={}, \
                 weighted={})",
                self.p, self.m, self.weighted, other.p, other.m, other.weighted
            ));
        }
        for shard in other.nodes.keys() {
            if self.nodes.contains_key(shard) {
                return invalid(format!("pca partial: shard {shard} present in both partials"));
            }
        }
        for (shard, node) in &other.nodes {
            self.nodes.insert(*shard, node.clone());
        }
        Ok(())
    }

    fn encode_payload(&self) -> Vec<u8> {
        let mut w = PayloadWriter::new();
        w.u64(self.p as u64);
        w.u64(self.m as u64);
        w.u8(self.weighted as u8);
        w.u64(self.nodes.len() as u64);
        for (shard, (mean, cov)) in &self.nodes {
            w.u32(*shard);
            w.blob(&mean.encode_payload());
            w.blob(&cov.encode_payload());
        }
        w.finish()
    }

    fn decode_payload(_version: u32, payload: &[u8]) -> Result<Self> {
        let mut r = PayloadReader::new(payload);
        let p = r.len()?;
        let m = r.len()?;
        let weighted = match r.u8()? {
            0 => false,
            1 => true,
            other => return corrupt(format!("pca partial: bad weighted flag {other}")),
        };
        let count = r.len()?;
        let mut out = PcaPartial::new(p, m, weighted);
        for _ in 0..count {
            let shard = r.u32()?;
            let mean = SparseMeanEstimator::decode_payload(1, r.blob()?)?;
            let cov = CovarianceEstimator::decode_payload(1, r.blob()?)?;
            if mean.shape() != (p, m) || cov.shape() != (p, m) || cov.is_weighted() != weighted {
                return corrupt(format!("pca partial: shard {shard} node config mismatch"));
            }
            let expect_scale = if weighted { Some(1.0) } else { None };
            if mean.scale_opt() != expect_scale {
                return corrupt(format!("pca partial: shard {shard} mean scale mismatch"));
            }
            if out.nodes.insert(shard, (mean, cov)).is_some() {
                return corrupt(format!("pca partial: duplicate shard {shard}"));
            }
        }
        r.finish()?;
        Ok(out)
    }
}

/// One shard's contribution to one Lloyd iteration.
#[derive(Clone, Debug)]
struct CenterNode {
    /// Masked center sums (p × k), exported from [`CenterStep`].
    sums: Mat,
    /// Per-cell observation counts (p × k).
    counts: Mat,
    /// Per-sample assignments in the shard's column order.
    assign: Vec<u32>,
    /// Eq. 34 objective contribution (sum of min masked distances).
    objective: f64,
}

/// One worker's Lloyd-iteration partial: the exported
/// [`CenterStep`] update **per shard**, merged by disjoint union and
/// finalized in shard-index order — the distributed form of one
/// iteration of sparsified K-means (Eq. 36 + 39), bitwise identical at
/// every partition and merge order.
#[derive(Clone, Debug)]
pub struct CenterPartial {
    p: usize,
    k: usize,
    nodes: BTreeMap<u32, CenterNode>,
}

/// A finalized [`CenterPartial`]: everything the Lloyd loop needs from
/// one full pass.
#[derive(Clone, Debug)]
pub struct CenterUpdate {
    /// Solved next centers (Eq. 39/40), p × k.
    pub centers: Mat,
    /// Per-sample assignments in global column order.
    pub assign: Vec<u32>,
    /// Eq. 34 objective over all shards.
    pub objective: f64,
}

impl CenterPartial {
    /// Empty partial for dimension `p` and `k` clusters.
    pub fn new(p: usize, k: usize) -> Self {
        CenterPartial { p, k, nodes: BTreeMap::new() }
    }

    /// Capture a completed [`CenterStep`] pass over exactly one shard's
    /// columns as that shard's node.
    pub fn insert_step(&mut self, shard: u32, step: &CenterStep) -> Result<()> {
        let (sums, counts) = step.export_update();
        if (sums.rows(), sums.cols()) != (self.p, self.k) {
            return invalid(format!(
                "center partial: step (p,k)=({},{}) does not match partial ({},{})",
                sums.rows(),
                sums.cols(),
                self.p,
                self.k
            ));
        }
        if self.nodes.contains_key(&shard) {
            return invalid(format!("center partial: shard {shard} folded twice"));
        }
        self.nodes.insert(
            shard,
            CenterNode {
                sums,
                counts,
                assign: step.assign().to_vec(),
                objective: step.objective(),
            },
        );
        Ok(())
    }

    /// Shard indices this partial covers (ascending).
    pub fn shards(&self) -> Vec<u32> {
        self.nodes.keys().copied().collect()
    }

    /// Samples assigned across all shards.
    pub fn n(&self) -> usize {
        self.nodes.values().map(|node| node.assign.len()).sum()
    }

    /// Fold the per-shard updates in shard-index order and solve the
    /// Eq. 39/40 system (`prev` supplies never-sampled coordinates).
    pub fn finalize(&self, prev: &Mat) -> Result<CenterUpdate> {
        if self.nodes.is_empty() {
            return invalid("center partial: nothing to finalize (no shards folded)");
        }
        let mut sums = Mat::zeros(self.p, self.k);
        let mut counts = Mat::zeros(self.p, self.k);
        let mut assign = Vec::with_capacity(self.n());
        let mut objective = 0.0;
        for node in self.nodes.values() {
            sums.axpy(1.0, &node.sums);
            counts.axpy(1.0, &node.counts);
            assign.extend_from_slice(&node.assign);
            objective += node.objective;
        }
        let centers = solve_centers(&sums, &counts, prev);
        Ok(CenterUpdate { centers, assign, objective })
    }

    /// Members per cluster under the merged assignment.
    pub fn cluster_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.k];
        for node in self.nodes.values() {
            for &a in &node.assign {
                sizes[crate::convert::u32_to_usize(a)] += 1;
            }
        }
        sizes
    }
}

impl PartialFit for CenterPartial {
    const KIND: u32 = kind::CENTER;
    const VERSION: u32 = 1;

    fn kind_name() -> &'static str {
        "center"
    }

    fn identity_like(&self) -> Self {
        CenterPartial::new(self.p, self.k)
    }

    fn merge_from(&mut self, other: &Self) -> Result<()> {
        if (self.p, self.k) != (other.p, other.k) {
            return invalid(format!(
                "cannot merge center partial (p={}, k={}) with (p={}, k={})",
                self.p, self.k, other.p, other.k
            ));
        }
        for shard in other.nodes.keys() {
            if self.nodes.contains_key(shard) {
                return invalid(format!("center partial: shard {shard} present in both partials"));
            }
        }
        for (shard, node) in &other.nodes {
            self.nodes.insert(*shard, node.clone());
        }
        Ok(())
    }

    fn encode_payload(&self) -> Vec<u8> {
        let mut w = PayloadWriter::new();
        w.u64(self.p as u64);
        w.u64(self.k as u64);
        w.u64(self.nodes.len() as u64);
        for (shard, node) in &self.nodes {
            w.u32(*shard);
            w.u64(node.assign.len() as u64);
            w.f64(node.objective);
            w.f64s(node.sums.as_slice());
            w.f64s(node.counts.as_slice());
            for &a in &node.assign {
                w.u32(a);
            }
        }
        w.finish()
    }

    fn decode_payload(_version: u32, payload: &[u8]) -> Result<Self> {
        let mut r = PayloadReader::new(payload);
        let p = r.len()?;
        let k = r.len()?;
        let count = r.len()?;
        let cells = p
            .checked_mul(k)
            .ok_or(())
            .or_else(|_| corrupt(format!("center partial: p*k overflows ({p}*{k})")))?;
        let mut out = CenterPartial::new(p, k);
        for _ in 0..count {
            let shard = r.u32()?;
            let n = r.len()?;
            let objective = r.f64()?;
            let sums = Mat::from_vec(p, k, r.f64s(cells)?)?;
            let counts = Mat::from_vec(p, k, r.f64s(cells)?)?;
            let mut assign = Vec::with_capacity(n.min(payload.len() / 4 + 1));
            for _ in 0..n {
                let a = r.u32()?;
                if crate::convert::u32_to_usize(a) >= k {
                    return corrupt(format!(
                        "center partial: shard {shard} assignment {a} out of range (k={k})"
                    ));
                }
                assign.push(a);
            }
            if out
                .nodes
                .insert(shard, CenterNode { sums, counts, assign, objective })
                .is_some()
            {
                return corrupt(format!("center partial: duplicate shard {shard}"));
            }
        }
        r.finish()?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;
    use crate::kmeans::NativeAssigner;
    use crate::rng::Pcg64;
    use crate::testing::fixtures::sparse_chunk;
    use crate::testing::prop::assert_mergeable;

    fn chunks(p: usize, m: usize, per: usize, count: usize) -> Vec<SparseChunk> {
        (0..count).map(|i| sparse_chunk(p, m, per, i * per, 40 + i as u64)).collect()
    }

    fn close(a: &[f64], b: &[f64]) -> bool {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| (x - y).abs() <= 1e-12 * x.abs().max(y.abs()).max(1.0))
    }

    #[test]
    fn mean_merge_laws() {
        let items: Vec<SparseMeanEstimator> = chunks(24, 6, 50, 5)
            .iter()
            .map(|c| {
                let mut e = SparseMeanEstimator::new(24, 6);
                e.accumulate(c);
                e
            })
            .collect();
        // float-direct accumulator: permutations re-associate the sums,
        // so equality is tolerance-based
        assert_mergeable(
            "mean_merge",
            &items,
            || SparseMeanEstimator::new(24, 6),
            |a, b| a.merge_from(b).unwrap(),
            |a, b| a.n() == b.n() && close(a.sum_raw(), b.sum_raw()),
        );
    }

    #[test]
    fn covariance_merge_laws() {
        let items: Vec<CovarianceEstimator> = chunks(16, 5, 40, 4)
            .iter()
            .map(|c| {
                let mut e = CovarianceEstimator::new(16, 5);
                e.accumulate(c);
                e
            })
            .collect();
        assert_mergeable(
            "covariance_merge",
            &items,
            || CovarianceEstimator::new(16, 5),
            |a, b| a.merge_from(b).unwrap(),
            |a, b| a.n() == b.n() && close(a.acc_raw().as_slice(), b.acc_raw().as_slice()),
        );
    }

    #[test]
    fn pca_partial_merge_laws_bitwise() {
        // per-shard map union: *bitwise* order/partition invariance
        let items: Vec<PcaPartial> = chunks(16, 5, 30, 6)
            .iter()
            .enumerate()
            .map(|(shard, c)| {
                let mut part = PcaPartial::new(16, 5, false);
                part.fold_chunk(shard as u32, c).unwrap();
                part
            })
            .collect();
        let bits_eq = |a: &PcaPartial, b: &PcaPartial| {
            if a.shards() != b.shards() {
                return false;
            }
            a.nodes.iter().zip(&b.nodes).all(|((_, x), (_, y))| {
                x.0.sum_raw().iter().zip(y.0.sum_raw()).all(|(u, v)| u.to_bits() == v.to_bits())
                    && x.1
                        .acc_raw()
                        .as_slice()
                        .iter()
                        .zip(y.1.acc_raw().as_slice())
                        .all(|(u, v)| u.to_bits() == v.to_bits())
            })
        };
        assert_mergeable(
            "pca_partial_merge",
            &items,
            || PcaPartial::new(16, 5, false),
            |a, b| a.merge_from(b).unwrap(),
            bits_eq,
        );
    }

    #[test]
    fn center_partial_merge_laws_bitwise() {
        let k = 3;
        let p = 16;
        let mut rng = Pcg64::seed(7);
        let centers = Mat::from_fn(p, k, |_, _| rng.normal());
        let items: Vec<CenterPartial> = chunks(p, 5, 30, 5)
            .iter()
            .enumerate()
            .map(|(shard, c)| {
                let mut step = CenterStep::new(p, k, 1);
                step.begin();
                step.fold(c, &centers, &NativeAssigner::new()).unwrap();
                let mut part = CenterPartial::new(p, k);
                part.insert_step(shard as u32, &step).unwrap();
                part
            })
            .collect();
        let bits_eq = |a: &CenterPartial, b: &CenterPartial| {
            a.shards() == b.shards()
                && a.nodes.iter().zip(&b.nodes).all(|((_, x), (_, y))| {
                    x.assign == y.assign
                        && x.objective.to_bits() == y.objective.to_bits()
                        && x.sums
                            .as_slice()
                            .iter()
                            .zip(y.sums.as_slice())
                            .all(|(u, v)| u.to_bits() == v.to_bits())
                })
        };
        assert_mergeable(
            "center_partial_merge",
            &items,
            || CenterPartial::new(p, k),
            |a, b| a.merge_from(b).unwrap(),
            bits_eq,
        );
        // and the merged finalize matches one step folding everything
        let mut whole = CenterStep::new(p, k, 1);
        whole.begin();
        for c in &chunks(p, 5, 30, 5) {
            whole.fold(c, &centers, &NativeAssigner::new()).unwrap();
        }
        let mut merged = CenterPartial::new(p, k);
        for it in &items {
            merged.merge_from(it).unwrap();
        }
        let update = merged.finalize(&centers).unwrap();
        assert_eq!(update.assign, whole.assign());
        let solved = whole.solve(&centers);
        for (a, b) in update.centers.as_slice().iter().zip(solved.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn round_trip_every_kind() {
        let c = sparse_chunk(16, 5, 40, 0, 11);

        let mut mean = SparseMeanEstimator::new(16, 5).with_scale(1.0);
        mean.accumulate(&c);
        let back = SparseMeanEstimator::from_bytes(&mean.to_bytes()).unwrap();
        assert_eq!(back.n(), mean.n());
        assert_eq!(back.scale_opt(), mean.scale_opt());
        assert!(back.sum_raw().iter().zip(mean.sum_raw()).all(|(a, b)| a.to_bits() == b.to_bits()));

        let mut cov = CovarianceEstimator::new(16, 5);
        cov.accumulate(&c);
        let back = CovarianceEstimator::from_bytes(&cov.to_bytes()).unwrap();
        assert_eq!(back.n(), cov.n());
        assert!(back
            .acc_raw()
            .as_slice()
            .iter()
            .zip(cov.acc_raw().as_slice())
            .all(|(a, b)| a.to_bits() == b.to_bits()));

        let mut hk = HkAccumulator::new(16, 5);
        hk.accumulate(&c);
        let back = HkAccumulator::from_bytes(&hk.to_bytes()).unwrap();
        assert_eq!(back.counts_raw(), hk.counts_raw());
        assert_eq!(back.n(), hk.n());

        let mut pca = PcaPartial::new(16, 5, false);
        pca.fold_chunk(0, &c).unwrap();
        pca.fold_chunk(3, &sparse_chunk(16, 5, 20, 40, 12)).unwrap();
        let back = PcaPartial::from_bytes(&pca.to_bytes()).unwrap();
        assert_eq!(back.shards(), pca.shards());
        assert_eq!(back.n(), pca.n());

        let centers = Mat::from_fn(16, 3, |i, j| ((i + 2 * j) % 5) as f64 - 2.0);
        let mut step = CenterStep::new(16, 3, 1);
        step.begin();
        step.fold(&c, &centers, &NativeAssigner::new()).unwrap();
        let mut cp = CenterPartial::new(16, 3);
        cp.insert_step(7, &step).unwrap();
        let back = CenterPartial::from_bytes(&cp.to_bytes()).unwrap();
        assert_eq!(back.shards(), vec![7]);
        let a = back.finalize(&centers).unwrap();
        let b = cp.finalize(&centers).unwrap();
        assert_eq!(a.assign, b.assign);
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
    }

    #[test]
    fn kind_and_version_mismatches_are_invalid() {
        let mut hk = HkAccumulator::new(8, 4);
        hk.accumulate(&sparse_chunk(8, 4, 10, 0, 3));
        let bytes = hk.to_bytes();
        // wrong kind for the decoder
        match SparseMeanEstimator::from_bytes(&bytes) {
            Err(Error::Invalid(msg)) => assert!(msg.contains("kind"), "{msg}"),
            other => panic!("expected Invalid, got {other:?}"),
        }
        // future version
        let future = super::super::encode_artifact(kind::HK, HkAccumulator::VERSION + 1, &[]);
        match HkAccumulator::from_bytes(&future) {
            Err(Error::Invalid(msg)) => assert!(msg.contains("newer"), "{msg}"),
            other => panic!("expected Invalid, got {other:?}"),
        }
    }

    #[test]
    fn tampered_payloads_are_typed_never_panic() {
        let mut pca = PcaPartial::new(8, 4, true);
        pca.fold_chunk(0, &sparse_chunk(8, 4, 12, 0, 5)).unwrap();
        let good = pca.to_bytes();
        // truncate at every boundary: envelope decode or payload decode
        // must return a typed error (the envelope CRC catches all of
        // these, but the payload reader is also exercised directly below)
        for cut in 0..good.len() {
            assert!(PcaPartial::from_bytes(&good[..cut]).is_err());
        }
        // a syntactically valid envelope around a damaged payload:
        // re-encode garbage payloads and check typed failure
        for garbage in [&[][..], &[1, 2, 3][..], &[0xFF; 64][..]] {
            let art = super::super::encode_artifact(kind::PCA, PcaPartial::VERSION, garbage);
            match PcaPartial::from_bytes(&art) {
                Err(Error::Corrupt(_)) | Err(Error::Invalid(_)) => {}
                other => panic!("garbage payload: expected typed error, got {other:?}"),
            }
        }
    }

    #[test]
    fn overlapping_shards_refuse_to_merge() {
        let c = sparse_chunk(8, 4, 10, 0, 3);
        let mut a = PcaPartial::new(8, 4, false);
        a.fold_chunk(2, &c).unwrap();
        let mut b = PcaPartial::new(8, 4, false);
        b.fold_chunk(2, &c).unwrap();
        match a.merge_from(&b) {
            Err(Error::Invalid(msg)) => assert!(msg.contains("shard 2"), "{msg}"),
            other => panic!("expected Invalid, got {other:?}"),
        }
    }

    #[test]
    fn mixed_calibrations_refuse_to_merge() {
        let mut a = SparseMeanEstimator::new(8, 4);
        let b = SparseMeanEstimator::new(8, 4).with_scale(1.0);
        assert!(matches!(a.merge_from(&b), Err(Error::Invalid(_))));
        let mut cu = CovarianceEstimator::new(8, 4);
        let cw = CovarianceEstimator::new_weighted(8, 4);
        assert!(matches!(cu.merge_from(&cw), Err(Error::Invalid(_))));
    }
}
