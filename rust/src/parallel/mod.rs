//! Repo-wide fork/join execution layer for the L3 hot paths.
//!
//! The pattern everywhere is the same: split an index space `0..n` into
//! contiguous ranges, run one worker per range on a scoped thread (the
//! same `crossbeam_utils::thread::scope` discipline as
//! `coordinator::pipeline`), and merge the per-range partials **in range
//! order** on the calling thread. Contiguous ranges + ordered merge is
//! what makes every consumer of this module bitwise deterministic: a
//! result never depends on thread scheduling, only on the (fixed) range
//! boundaries — and consumers that partition the *output* space (row or
//! column ranges of an accumulator) are bitwise independent of the worker
//! count too, because each output cell is touched by exactly one worker
//! in the same element order as the serial loop.
//!
//! `workers <= 1` (or a single range) never spawns a thread: the work
//! runs inline on the caller, so the serial path stays byte-identical to
//! the pre-parallel code.

use std::ops::Range;

/// Split `0..n` into at most `workers` contiguous, non-empty, near-equal
/// ranges covering `0..n` in order. Fewer ranges are returned when
/// `n < workers`; `n == 0` yields no ranges.
pub fn split_ranges(n: usize, workers: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let w = workers.max(1).min(n);
    let base = n / w;
    let rem = n % w;
    let mut out = Vec::with_capacity(w);
    let mut start = 0usize;
    for t in 0..w {
        let len = base + usize::from(t < rem);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// Split `0..n` into at most `workers` contiguous ranges of near-equal
/// *weight*, for index spaces with skewed per-index cost (e.g. the
/// lower-triangle covariance scatter, where column `j` owns `p - j`
/// output rows). Every range is non-empty and the union covers `0..n`.
pub fn split_ranges_by_weight(
    n: usize,
    workers: usize,
    weight: impl Fn(usize) -> f64,
) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let w = workers.max(1).min(n);
    if w == 1 {
        return vec![0..n];
    }
    let total: f64 = (0..n).map(&weight).sum();
    if !(total > 0.0) {
        return split_ranges(n, workers);
    }
    let mut out = Vec::with_capacity(w);
    let mut start = 0usize;
    let mut cum = 0.0;
    for j in 0..n {
        cum += weight(j);
        let ranges_left_after_this = w - out.len() - 1;
        let cut = total * (out.len() + 1) as f64 / w as f64;
        if out.len() + 1 < w && cum >= cut && (n - (j + 1)) >= ranges_left_after_this {
            out.push(start..j + 1);
            start = j + 1;
        }
    }
    out.push(start..n);
    out
}

/// Run `work` over each range on scoped threads (first range inline on
/// the caller), returning the per-range results **in range order** — the
/// deterministic-merge contract. A single range runs entirely inline.
pub fn run_ranges<T, F>(ranges: Vec<Range<usize>>, work: F) -> Vec<T>
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
{
    if ranges.len() <= 1 {
        return ranges.into_iter().map(work).collect();
    }
    let work = &work;
    crossbeam_utils::thread::scope(|scope| {
        let (first, rest) = ranges.split_first().expect("len > 1");
        let handles: Vec<_> = rest
            .iter()
            .map(|r| {
                let r = r.clone();
                scope.spawn(move |_| work(r))
            })
            .collect();
        let mut out = Vec::with_capacity(ranges.len());
        out.push(work(first.clone()));
        for h in handles {
            out.push(h.join().expect("parallel worker panicked"));
        }
        out
    })
    .expect("parallel scope panicked")
}

/// Convenience: equal split of `0..n` over `workers`, then [`run_ranges`].
pub fn map_ranges<T, F>(n: usize, workers: usize, work: F) -> Vec<T>
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
{
    run_ranges(split_ranges(n, workers), work)
}

/// Run pre-carved `(range, panel)` jobs on scoped threads, first job
/// inline on the caller — the [`run_ranges`] discipline for consumers
/// that partition a mutable buffer into per-range panels (via
/// [`split_col_panels`]). A single job runs entirely inline, so the
/// serial path stays byte-identical to a plain loop.
pub fn run_panel_jobs<'p, F>(jobs: Vec<(Range<usize>, &'p mut [f64])>, work: F)
where
    F: Fn(Range<usize>, &mut [f64]) + Sync,
{
    if jobs.len() <= 1 {
        for (r, panel) in jobs {
            work(r, panel);
        }
        return;
    }
    let work = &work;
    crossbeam_utils::thread::scope(|scope| {
        let mut iter = jobs.into_iter();
        let first = iter.next().expect("len > 1");
        let handles: Vec<_> = iter
            .map(|(r, panel)| scope.spawn(move |_| work(r, panel)))
            .collect();
        let (r, panel) = first;
        work(r, panel);
        for h in handles {
            h.join().expect("panel worker panicked");
        }
    })
    .expect("panel scope panicked");
}

/// Split a column-major `rows × cols` buffer into disjoint mutable column
/// panels, one per range. `ranges` must be contiguous, in order, and
/// cover `0..cols` (exactly what [`split_ranges`] /
/// [`split_ranges_by_weight`] produce) — each panel `t` is the contiguous
/// slice holding columns `ranges[t]`.
pub fn split_col_panels<'a>(
    data: &'a mut [f64],
    rows: usize,
    ranges: &[Range<usize>],
) -> Vec<&'a mut [f64]> {
    let mut out = Vec::with_capacity(ranges.len());
    let mut rest = data;
    let mut consumed = 0usize;
    for r in ranges {
        debug_assert_eq!(r.start * rows, consumed, "ranges must be contiguous from 0");
        let take = (r.end - r.start) * rows;
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
        out.push(head);
        rest = tail;
        consumed += take;
    }
    debug_assert!(rest.is_empty(), "ranges must cover all columns");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_covers_in_order_and_balances() {
        for (n, w) in [(10, 3), (3, 10), (1, 1), (7, 7), (1000, 4)] {
            let r = split_ranges(n, w);
            assert!(r.len() <= w && r.len() <= n);
            assert_eq!(r.first().unwrap().start, 0);
            assert_eq!(r.last().unwrap().end, n);
            for pair in r.windows(2) {
                assert_eq!(pair[0].end, pair[1].start);
            }
            let (min, max) = r
                .iter()
                .map(|x| x.len())
                .fold((usize::MAX, 0), |(a, b), l| (a.min(l), b.max(l)));
            assert!(max - min <= 1, "unbalanced: {r:?}");
        }
        assert!(split_ranges(0, 4).is_empty());
    }

    #[test]
    fn weighted_split_equalizes_triangular_load() {
        let n = 256;
        let weight = |j: usize| (n - j) as f64;
        let r = split_ranges_by_weight(n, 4, weight);
        assert_eq!(r.len(), 4);
        assert_eq!(r.first().unwrap().start, 0);
        assert_eq!(r.last().unwrap().end, n);
        let loads: Vec<f64> =
            r.iter().map(|rr| rr.clone().map(weight).sum::<f64>()).collect();
        let total: f64 = loads.iter().sum();
        for l in &loads {
            assert!(
                (l - total / 4.0).abs() < total * 0.1,
                "imbalanced weighted split: {loads:?}"
            );
        }
        // equal-width split would put ~44% of the triangle in range 0
        assert!(r[0].len() < n / 3, "first range should be narrow: {r:?}");
    }

    #[test]
    fn map_ranges_is_ordered_and_complete() {
        for workers in [1usize, 2, 3, 8] {
            let parts = map_ranges(100, workers, |r| r.clone());
            let flat: Vec<usize> = parts.into_iter().flatten().collect();
            assert_eq!(flat, (0..100).collect::<Vec<_>>(), "workers={workers}");
        }
    }

    #[test]
    fn map_ranges_sums_match_serial() {
        let data: Vec<f64> = (0..1000).map(|i| (i as f64).sin()).collect();
        let serial: f64 = data.iter().sum();
        for workers in [1usize, 2, 4] {
            let partials = map_ranges(data.len(), workers, |r| data[r].iter().sum::<f64>());
            let merged: f64 = partials.iter().sum();
            assert!((merged - serial).abs() < 1e-9);
        }
    }

    #[test]
    fn col_panels_are_disjoint_views() {
        let rows = 3;
        let mut data = vec![0.0f64; rows * 8];
        let ranges = split_ranges(8, 3);
        let panels = split_col_panels(&mut data, rows, &ranges);
        assert_eq!(panels.len(), 3);
        let total: usize = panels.iter().map(|p| p.len()).sum();
        assert_eq!(total, rows * 8);
        for (t, p) in panels.into_iter().enumerate() {
            for v in p.iter_mut() {
                *v = t as f64;
            }
        }
        // column j belongs to the range containing j
        for (t, r) in ranges.iter().enumerate() {
            for j in r.clone() {
                for i in 0..rows {
                    assert_eq!(data[j * rows + i], t as f64);
                }
            }
        }
    }

    #[test]
    fn panel_jobs_cover_every_cell_at_any_width() {
        let rows = 2;
        let cols = 9;
        for workers in [1usize, 3, 9] {
            let mut data = vec![0.0f64; rows * cols];
            let ranges = split_ranges(cols, workers);
            let panels = split_col_panels(&mut data, rows, &ranges);
            let jobs: Vec<_> = ranges.into_iter().zip(panels).collect();
            run_panel_jobs(jobs, |r: Range<usize>, panel: &mut [f64]| {
                for (local, j) in r.enumerate() {
                    for i in 0..rows {
                        panel[local * rows + i] = (j * rows + i) as f64;
                    }
                }
            });
            for (pos, v) in data.iter().enumerate() {
                assert_eq!(*v, pos as f64, "workers={workers}");
            }
        }
    }

    #[test]
    fn parallel_writes_land_in_own_panel() {
        let rows = 4;
        let cols = 64;
        let mut data = vec![0.0f64; rows * cols];
        let ranges = split_ranges(cols, 4);
        let panels = split_col_panels(&mut data, rows, &ranges);
        let jobs: Vec<_> = ranges.iter().cloned().zip(panels).collect();
        crossbeam_utils::thread::scope(|scope| {
            for (r, panel) in jobs {
                scope.spawn(move |_| {
                    for (local, j) in r.enumerate() {
                        for i in 0..rows {
                            panel[local * rows + i] = (j * rows + i) as f64;
                        }
                    }
                });
            }
        })
        .unwrap();
        for (pos, v) in data.iter().enumerate() {
            assert_eq!(*v, pos as f64);
        }
    }
}
