//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! Grammar: `pds <command> [positional...] [--flag] [--key value]`.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    /// Positional arguments after the command name.
    pub positional: Vec<String>,
    /// `--key value` options.
    options: BTreeMap<String, String>,
    /// Bare `--flag`s.
    flags: Vec<String>,
}

impl Args {
    /// Parse raw arguments (not including argv[0]/command).
    pub fn parse(raw: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(name) = a.strip_prefix("--") {
                // value if next token exists and is not itself an option
                if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.options.insert(name.to_string(), raw[i + 1].clone());
                    i += 2;
                } else {
                    out.flags.push(name.to_string());
                    i += 1;
                }
            } else {
                out.positional.push(a.clone());
                i += 1;
            }
        }
        Ok(out)
    }

    /// Was bare `--name` passed?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw value of `--name value`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Typed option with default.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Invalid(format!("--{name}: cannot parse {v:?}"))),
        }
    }

    /// Comma-separated list option.
    pub fn get_list_f64(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.options.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse::<f64>()
                        .map_err(|_| Error::Invalid(format!("--{name}: bad float {s:?}")))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_mix() {
        let a = Args::parse(&strv(&["fig1", "--runs", "100", "--full", "--gamma", "0.1,0.2"]))
            .unwrap();
        assert_eq!(a.positional, vec!["fig1"]);
        assert_eq!(a.get("runs"), Some("100"));
        assert!(a.flag("full"));
        assert_eq!(a.get_list_f64("gamma", &[]).unwrap(), vec![0.1, 0.2]);
    }

    #[test]
    fn typed_defaults() {
        let a = Args::parse(&strv(&["--n", "50"])).unwrap();
        assert_eq!(a.get_parse("n", 7usize).unwrap(), 50);
        assert_eq!(a.get_parse("missing", 7usize).unwrap(), 7);
        assert!(a.get_parse::<usize>("n", 0).is_ok());
    }

    #[test]
    fn bad_value_errors() {
        let a = Args::parse(&strv(&["--n", "xyz"])).unwrap();
        assert!(a.get_parse::<usize>("n", 0).is_err());
    }

    #[test]
    fn negative_number_is_value_not_flag() {
        let a = Args::parse(&strv(&["--shift", "-2"])).unwrap();
        assert_eq!(a.get("shift"), Some("-2"));
    }
}
