//! The paper's compression operator: ROS preconditioning + uniform m-of-p
//! element sampling, fused into a single pass over each chunk — plus a
//! pluggable scheme layer that generalizes the element-selection law
//! (uniform with/without preconditioning, hybrid-(ℓ1,ℓ2) importance
//! sampling) behind one [`SamplingScheme`] trait.
//!
//! Under the default [`Scheme::Precond`], every sample gets an
//! *independent* sampling matrix `R_i` (m distinct canonical basis
//! vectors, uniform without replacement). Per-column RNG streams are
//! forked from `(seed, global column index)`, so the output is invariant
//! to chunk boundaries and worker scheduling — the coordinator's
//! reproducibility guarantee, upheld by every scheme.

mod scheme;

pub use scheme::{
    HybridL1L2, PreconditionedUniform, SamplingScheme, Scheme, UniformNoPrecondition,
    DEFAULT_HYBRID_L1_MIX,
};

use crate::error::{invalid, Result};
use crate::linalg::Mat;
use crate::rng::Pcg64;
use crate::sparse::SparseChunk;
use crate::transform::{is_pow2, Ros, TransformKind};

/// Configuration of the sparsification front-end.
#[derive(Clone, Copy, Debug)]
pub struct SparsifyConfig {
    /// Compression factor γ = m/p (0 < γ ≤ 1). `m = max(2, round(γ·p))`.
    pub gamma: f64,
    /// Which orthonormal transform `H` to use.
    pub transform: TransformKind,
    /// Root seed for the sign diagonal and all sampling masks.
    pub seed: u64,
}

impl Default for SparsifyConfig {
    fn default() -> Self {
        SparsifyConfig { gamma: 0.1, transform: TransformKind::Hadamard, seed: 0 }
    }
}

/// Draw `m` distinct indices from `{0..p}` uniformly without replacement
/// (partial Fisher–Yates over a caller-provided permutation scratch of
/// length `p`), writing them sorted into `out`.
///
/// This is the **reference** implementation: the identity reset makes
/// every draw cost O(p) regardless of `m`. The compression hot path uses
/// [`IndexSampler`], which consumes the same RNG stream and produces
/// byte-identical output in O(m) per draw.
pub fn sample_indices(rng: &mut Pcg64, p: usize, out: &mut [u32], perm: &mut [u32]) {
    let m = out.len();
    debug_assert!(m <= p && perm.len() == p);
    // reset scratch
    for (i, v) in perm.iter_mut().enumerate() {
        *v = i as u32;
    }
    for i in 0..m {
        let j = i + rng.next_range((p - i) as u32) as usize;
        perm.swap(i, j);
    }
    out.copy_from_slice(&perm[..m]);
    out.sort_unstable();
}

/// O(m) without-replacement index sampler — the [`sample_indices`]
/// partial Fisher–Yates with the O(p) identity reset replaced by an
/// epoch-tagged sparse overlay of the virtual permutation.
///
/// `perm[j]` is materialized only for slots a swap has touched
/// (`epoch[j] == cur`); every other slot implicitly holds `j`. Bumping
/// `cur` invalidates the whole overlay in O(1), so a draw costs
/// O(m log m) (the sort) instead of O(p) — at γ = 0.05, p = 4096 the
/// reset was ~95% of the per-sample mask cost (§Perf log). The draw
/// sequence consumes the RNG identically to [`sample_indices`], so masks
/// — and therefore compressed chunks — are **byte-identical** to the
/// reference, preserving the coordinator's reproducibility guarantee.
///
/// (Floyd's algorithm was the other O(m) candidate; it maps the RNG
/// stream to a *different* mask set, which would silently re-randomize
/// every seeded experiment in the repo. The sparse Fisher–Yates gets the
/// same asymptotics with exact stream compatibility.)
#[derive(Clone, Debug)]
pub struct IndexSampler {
    p: usize,
    /// Overlay values: `perm[j] = val[j]` iff `epoch[j] == cur`.
    val: Vec<u32>,
    /// Epoch tag per slot; stale tags mean "identity".
    epoch: Vec<u32>,
    /// Current draw's epoch.
    cur: u32,
}

impl IndexSampler {
    /// Build a sampler over `{0..p}` (scratch is allocated once, reused
    /// across draws).
    pub fn new(p: usize) -> Self {
        IndexSampler { p, val: vec![0; p], epoch: vec![0; p], cur: 0 }
    }

    /// Ambient dimension this sampler draws from.
    pub fn p(&self) -> usize {
        self.p
    }

    #[inline]
    fn lookup(&self, j: usize) -> u32 {
        if self.epoch[j] == self.cur {
            self.val[j]
        } else {
            j as u32
        }
    }

    /// Draw `out.len()` distinct indices from `{0..p}` uniformly without
    /// replacement, sorted. Same contract (and same RNG consumption) as
    /// [`sample_indices`].
    pub fn sample(&mut self, rng: &mut Pcg64, out: &mut [u32]) {
        let m = out.len();
        debug_assert!(m <= self.p);
        self.cur = self.cur.wrapping_add(1);
        if self.cur == 0 {
            // epoch counter wrapped: stale tags from 2^32 draws ago would
            // read as fresh; clear them once and restart at epoch 1
            self.epoch.fill(0);
            self.cur = 1;
        }
        for i in 0..m {
            let j = i + rng.next_range((self.p - i) as u32) as usize;
            // virtual swap(perm[i], perm[j]): slot i is never read again
            // (every future access is to a slot > i), so only slot j
            // needs materializing
            out[i] = self.lookup(j);
            self.val[j] = self.lookup(i);
            self.epoch[j] = self.cur;
        }
        out.sort_unstable();
    }
}

/// The fused precondition+sample operator.
///
/// If the configured transform is Hadamard and `p` is not a power of two,
/// the operator transparently zero-pads to the next power of two
/// (`p_work`), preconditions and samples in the padded space, and reports
/// `p()` = `p_work`. Zero-padding composes with an orthonormal map, so all
/// estimator guarantees hold in the padded space; the adjoint un-pads.
///
/// # Example
///
/// ```
/// use pds::linalg::Mat;
/// use pds::rng::Pcg64;
/// use pds::sampling::{Sparsifier, SparsifyConfig};
/// use pds::transform::TransformKind;
///
/// let cfg = SparsifyConfig { gamma: 0.25, transform: TransformKind::Hadamard, seed: 7 };
/// let sp = Sparsifier::new(64, cfg)?;
/// assert_eq!(sp.m(), 16); // keeps m = γ·p entries per sample
///
/// let mut rng = Pcg64::seed(1);
/// let x = Mat::from_fn(64, 10, |_, _| rng.normal());
/// let chunk = sp.compress_chunk(&x, 0)?; // precondition + sample, one pass
/// assert_eq!(chunk.n(), 10);
/// assert_eq!(chunk.m(), 16);
///
/// // Masks are keyed on the global column index, so chunk boundaries
/// // never change the output:
/// let left = sp.compress_chunk(&x.col_range(0, 4), 0)?;
/// assert_eq!(left.col_indices(2), chunk.col_indices(2));
/// # Ok::<(), pds::Error>(())
/// ```
#[derive(Clone)]
pub struct Sparsifier {
    ros: Ros,
    /// Original ambient dimension (before any padding).
    p_orig: usize,
    /// Working dimension (= p_orig, or next pow2 when padded).
    p_work: usize,
    m: usize,
    seed: u64,
    /// Element-selection law (default [`Scheme::Precond`]).
    scheme: Scheme,
}

impl Sparsifier {
    /// Build the operator for data of dimension `p` (padding to the next
    /// power of two when the Hadamard transform requires it), using the
    /// paper's default [`Scheme::Precond`] element-selection law.
    pub fn new(p: usize, cfg: SparsifyConfig) -> Result<Self> {
        Self::with_scheme(p, cfg, Scheme::Precond)
    }

    /// Build the operator with an explicit element-sampling [`Scheme`].
    /// `Scheme::Precond` is byte-identical to [`new`](Self::new).
    ///
    /// The ROS instance is constructed for every scheme (it also anchors
    /// the seed-stream layout); for the raw-domain schemes it is never
    /// *applied*, which is free under Hadamard (a sign vector) but pays
    /// the O(p²) DCT plan precompute under `TransformKind::Dct` — prefer
    /// Hadamard for large-p raw-domain sampling.
    pub fn with_scheme(p: usize, cfg: SparsifyConfig, scheme: Scheme) -> Result<Self> {
        if !(cfg.gamma > 0.0 && cfg.gamma <= 1.0) {
            return invalid(format!("gamma must be in (0,1], got {}", cfg.gamma));
        }
        let p_work = match cfg.transform {
            TransformKind::Hadamard if !is_pow2(p) => p.next_power_of_two(),
            _ => p,
        };
        // the clamp below has min = 2: a working dimension under 2 would
        // panic (`clamp` with min > max) and cannot satisfy the m >= 2
        // estimator requirement anyway — reject it as a typed error
        if p_work < 2 {
            return invalid(format!(
                "Sparsifier: dimension p = {p} (working dimension {p_work}) is below the \
                 minimum of 2"
            ));
        }
        let m = ((cfg.gamma * p_work as f64).round() as usize).clamp(2, p_work);
        let mut rng = Pcg64::seed(cfg.seed);
        let ros = Ros::new(p_work, cfg.transform, &mut rng)?;
        Ok(Sparsifier { ros, p_orig: p, p_work, m, seed: cfg.seed, scheme })
    }

    /// Working (possibly padded) dimension — the `p` of downstream chunks.
    pub fn p(&self) -> usize {
        self.p_work
    }

    /// Original data dimension.
    pub fn p_orig(&self) -> usize {
        self.p_orig
    }

    /// Kept entries per sample.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Effective compression factor m / p_work.
    pub fn gamma(&self) -> f64 {
        self.m as f64 / self.p_work as f64
    }

    /// The sampled ROS instance (sign diagonal + transform plan).
    pub fn ros(&self) -> &Ros {
        &self.ros
    }

    /// Root seed the sign diagonal and all sampling masks derive from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The element-sampling [`Scheme`] this operator applies.
    pub fn scheme(&self) -> Scheme {
        self.scheme
    }

    /// Whether chunks carry importance-weighted with-replacement slots
    /// (see [`Scheme::weighted`]) — selects the estimators' weighted
    /// calibration and mean scale `1` downstream.
    pub fn weighted(&self) -> bool {
        self.scheme.weighted()
    }

    /// Compress a dense chunk (`p_orig × n`, samples as columns) whose
    /// first column has global index `start_col`. One pass per column:
    /// precondition (schemes that ask for it), then let the scheme select
    /// the mask and stored values. Under [`Scheme::Uniform`] /
    /// [`Scheme::Hybrid`] no ROS is applied — the column is sampled in
    /// the raw (zero-padded) domain.
    pub fn compress_chunk(&self, x: &Mat, start_col: usize) -> Result<SparseChunk> {
        if x.rows() != self.p_orig {
            return invalid(format!("chunk rows {} != p {}", x.rows(), self.p_orig));
        }
        let scheme = self.scheme.instance();
        self.compress_with(x, start_col, scheme, scheme.preconditions())
    }

    /// Sparsify *without* preconditioning (the paper's "no precondition"
    /// ablation arm — Figs 7/10, Table I/III). For the uniform schemes
    /// masks are drawn from the same streams as
    /// [`compress_chunk`](Self::compress_chunk); for [`Scheme::Hybrid`]
    /// (which never preconditions) this is identical to `compress_chunk`.
    pub fn compress_chunk_no_precondition(&self, x: &Mat, start_col: usize) -> Result<SparseChunk> {
        if x.rows() != self.p_orig {
            return invalid(format!("chunk rows {} != p {}", x.rows(), self.p_orig));
        }
        let scheme = match self.scheme {
            // the no-ROS arm of the preconditioned scheme is exactly the
            // uniform scheme (same masks, raw values)
            Scheme::Precond => Scheme::Uniform.instance(),
            s => s.instance(),
        };
        self.compress_with(x, start_col, scheme, false)
    }

    /// Shared compress loop: pad each column, optionally precondition,
    /// fork the per-column RNG off the global column index, and let the
    /// scheme fill the mask + values.
    fn compress_with(
        &self,
        x: &Mat,
        start_col: usize,
        scheme: &dyn SamplingScheme,
        precondition: bool,
    ) -> Result<SparseChunk> {
        let n = x.cols();
        let mut out = SparseChunk::with_capacity(self.p_work, self.m, n, start_col);
        let mut buf = vec![0.0f64; self.p_work];
        let mut scratch = vec![0.0f64; self.p_work];
        let mut wscratch = vec![0.0f64; self.p_work];
        let mut sampler = IndexSampler::new(self.p_work);
        let mask_root = Pcg64::seed(self.seed ^ 0x9E37_79B9_7F4A_7C15);
        for i in 0..n {
            // pad (+ precondition when the scheme samples the ROS domain)
            buf[..self.p_orig].copy_from_slice(x.col(i));
            buf[self.p_orig..].fill(0.0);
            if precondition {
                self.ros.apply_col(&mut buf, &mut scratch);
            }
            // per-sample stream from a fork keyed on the global column
            // index — the chunk-boundary-invariance contract
            let mut crng = mask_root.fork((start_col + i) as u64);
            let (idx, vals) = out.col_mut(i);
            scheme.sample_column(&buf, &mut crng, &mut sampler, idx, vals, &mut wscratch);
        }
        Ok(out)
    }

    /// Un-mix a matrix of centers/estimates from the preconditioned domain
    /// back to the original coordinates (paper Eq. 32), dropping padding.
    pub fn unmix(&self, mu_precond: &Mat) -> Mat {
        assert_eq!(mu_precond.rows(), self.p_work);
        let mut y = mu_precond.clone();
        self.ros.adjoint_inplace(&mut y);
        if self.p_work == self.p_orig {
            y
        } else {
            let mut out = Mat::zeros(self.p_orig, y.cols());
            for j in 0..y.cols() {
                out.col_mut(j).copy_from_slice(&y.col(j)[..self.p_orig]);
            }
            out
        }
    }

    /// Drop padding rows only (no adjoint transform) — the center
    /// recovery for the *no-preconditioning* ablation arm.
    pub fn truncate(&self, mat: &Mat) -> Mat {
        assert_eq!(mat.rows(), self.p_work);
        if self.p_work == self.p_orig {
            return mat.clone();
        }
        let mut out = Mat::zeros(self.p_orig, mat.cols());
        for j in 0..mat.cols() {
            out.col_mut(j).copy_from_slice(&mat.col(j)[..self.p_orig]);
        }
        out
    }

    /// Precondition a dense chunk (pad + HD), without sampling — used by
    /// oracle computations in tests/experiments.
    pub fn precondition_dense(&self, x: &Mat) -> Mat {
        assert_eq!(x.rows(), self.p_orig);
        let mut out = Mat::zeros(self.p_work, x.cols());
        let mut scratch = vec![0.0; self.p_work];
        for j in 0..x.cols() {
            out.col_mut(j)[..self.p_orig].copy_from_slice(x.col(j));
            self.ros.apply_col(out.col_mut(j), &mut scratch);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::forall;

    #[test]
    fn sample_indices_properties() {
        forall("sample_indices", 50, |g| {
            let p = g.int(2, 200) as usize;
            let m = g.int(1, p as i64) as usize;
            let mut rng = Pcg64::seed(g.int(0, 1 << 40) as u64);
            let mut out = vec![0u32; m];
            let mut perm = vec![0u32; p];
            sample_indices(&mut rng, p, &mut out, &mut perm);
            for w in out.windows(2) {
                assert!(w[0] < w[1], "sorted+distinct violated: {out:?}");
            }
            assert!(*out.last().unwrap() < p as u32);
        });
    }

    #[test]
    fn index_sampler_matches_dense_reference_bytewise() {
        // the O(m) sampler must replicate the O(p)-reset Fisher–Yates
        // draw for draw, including across reuse of one sampler instance
        forall("index_sampler_equiv", 60, |g| {
            let p = g.int(2, 300) as usize;
            let m = g.int(1, p as i64) as usize;
            let seed = g.int(0, 1 << 40) as u64;
            let mut dense_rng = Pcg64::seed(seed);
            let mut sparse_rng = Pcg64::seed(seed);
            let mut dense = vec![0u32; m];
            let mut sparse = vec![0u32; m];
            let mut perm = vec![0u32; p];
            let mut sampler = IndexSampler::new(p);
            for draw in 0..4 {
                sample_indices(&mut dense_rng, p, &mut dense, &mut perm);
                sampler.sample(&mut sparse_rng, &mut sparse);
                assert_eq!(dense, sparse, "p={p} m={m} draw={draw}");
            }
        });
    }

    #[test]
    fn index_sampler_uniform_marginals() {
        // Lemma B5 for the hot-path sampler: P[keep j] = m/p for every j,
        // with one sampler instance reused across all trials (exercising
        // the epoch overlay)
        let (p, m, trials) = (32usize, 8usize, 40_000usize);
        let mut rng = Pcg64::seed(42);
        let mut counts = vec![0usize; p];
        let mut out = vec![0u32; m];
        let mut sampler = IndexSampler::new(p);
        for _ in 0..trials {
            sampler.sample(&mut rng, &mut out);
            for &j in &out {
                counts[j as usize] += 1;
            }
        }
        let expect = trials as f64 * m as f64 / p as f64;
        for &c in &counts {
            assert!(
                (c as f64 - expect).abs() < 5.0 * (expect * (1.0 - m as f64 / p as f64)).sqrt(),
                "count {c} vs expected {expect}"
            );
        }
    }

    #[test]
    fn index_sampler_epoch_wrap_stays_correct() {
        // force the epoch counter over the u32 boundary; draws on either
        // side must stay valid and keep matching the dense reference
        let p = 16usize;
        let m = 6usize;
        let mut sampler = IndexSampler::new(p);
        sampler.cur = u32::MAX - 2;
        sampler.epoch.fill(u32::MAX - 3);
        let mut dense_rng = Pcg64::seed(77);
        let mut sparse_rng = Pcg64::seed(77);
        let mut dense = vec![0u32; m];
        let mut sparse = vec![0u32; m];
        let mut perm = vec![0u32; p];
        for draw in 0..8 {
            sample_indices(&mut dense_rng, p, &mut dense, &mut perm);
            sampler.sample(&mut sparse_rng, &mut sparse);
            assert_eq!(dense, sparse, "draw {draw} across epoch wrap");
            for w in sparse.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }

    #[test]
    fn sample_indices_uniform_marginals() {
        // Lemma B5: P[keep coordinate j] = m/p for every j.
        let (p, m, trials) = (32usize, 8usize, 40_000usize);
        let mut rng = Pcg64::seed(42);
        let mut counts = vec![0usize; p];
        let mut out = vec![0u32; m];
        let mut perm = vec![0u32; p];
        for _ in 0..trials {
            sample_indices(&mut rng, p, &mut out, &mut perm);
            for &j in &out {
                counts[j as usize] += 1;
            }
        }
        let expect = trials as f64 * m as f64 / p as f64;
        for &c in &counts {
            assert!(
                (c as f64 - expect).abs() < 5.0 * (expect * (1.0 - m as f64 / p as f64)).sqrt(),
                "count {c} vs expected {expect}"
            );
        }
    }

    #[test]
    fn compress_chunk_keeps_preconditioned_values() {
        let p = 64;
        let cfg = SparsifyConfig { gamma: 0.25, transform: TransformKind::Hadamard, seed: 5 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let mut rng = Pcg64::seed(9);
        let x = Mat::from_fn(p, 10, |_, _| rng.normal());
        let y = sp.precondition_dense(&x);
        let chunk = sp.compress_chunk(&x, 0).unwrap();
        chunk.validate().unwrap();
        assert_eq!(chunk.m(), 16);
        for i in 0..10 {
            for (idx, val) in chunk.col_indices(i).iter().zip(chunk.col_values(i)) {
                assert!((val - y.get(*idx as usize, i)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn chunk_boundaries_do_not_change_output() {
        let p = 32;
        let cfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 11 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let mut rng = Pcg64::seed(13);
        let x = Mat::from_fn(p, 20, |_, _| rng.normal());
        let whole = sp.compress_chunk(&x, 0).unwrap();
        let first = sp.compress_chunk(&x.col_range(0, 12), 0).unwrap();
        let second = sp.compress_chunk(&x.col_range(12, 20), 12).unwrap();
        for i in 0..12 {
            assert_eq!(whole.col_indices(i), first.col_indices(i));
            assert_eq!(whole.col_values(i), first.col_values(i));
        }
        for i in 0..8 {
            assert_eq!(whole.col_indices(12 + i), second.col_indices(i));
            assert_eq!(whole.col_values(12 + i), second.col_values(i));
        }
    }

    #[test]
    fn padding_for_non_pow2_hadamard() {
        let p = 100;
        let cfg = SparsifyConfig { gamma: 0.25, transform: TransformKind::Hadamard, seed: 1 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        assert_eq!(sp.p(), 128);
        assert_eq!(sp.p_orig(), 100);
        assert_eq!(sp.m(), 32);
        let mut rng = Pcg64::seed(2);
        let x = Mat::from_fn(p, 4, |_, _| rng.normal());
        let chunk = sp.compress_chunk(&x, 0).unwrap();
        assert_eq!(chunk.p(), 128);
        // unmix of a preconditioned dense chunk recovers the original
        let y = sp.precondition_dense(&x);
        let back = sp.unmix(&y);
        assert!((back.sub(&x)).max_abs() < 1e-9);
    }

    #[test]
    fn no_precondition_keeps_raw_values() {
        let p = 16;
        let cfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 3 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let mut rng = Pcg64::seed(4);
        let x = Mat::from_fn(p, 6, |_, _| rng.normal());
        let chunk = sp.compress_chunk_no_precondition(&x, 0).unwrap();
        for i in 0..6 {
            for (idx, val) in chunk.col_indices(i).iter().zip(chunk.col_values(i)) {
                assert_eq!(*val, x.get(*idx as usize, i));
            }
        }
    }

    #[test]
    fn masks_match_between_precond_and_not() {
        // Both arms of the ablation must see identical masks so the
        // comparison isolates the preconditioner.
        let p = 32;
        let cfg = SparsifyConfig { gamma: 0.25, transform: TransformKind::Hadamard, seed: 21 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let mut rng = Pcg64::seed(22);
        let x = Mat::from_fn(p, 5, |_, _| rng.normal());
        let a = sp.compress_chunk(&x, 0).unwrap();
        let b = sp.compress_chunk_no_precondition(&x, 0).unwrap();
        for i in 0..5 {
            assert_eq!(a.col_indices(i), b.col_indices(i));
        }
    }

    #[test]
    fn dimension_below_two_is_a_typed_error_not_a_panic() {
        // regression: `((γ·p).round() as usize).clamp(2, p_work)` panics
        // when p_work < 2 (clamp with min > max); p < 2 must surface as
        // Error::Invalid instead
        for p in [0usize, 1] {
            for kind in [TransformKind::Hadamard, TransformKind::Dct] {
                let cfg = SparsifyConfig { gamma: 0.5, transform: kind, seed: 1 };
                match Sparsifier::new(p, cfg) {
                    Err(crate::error::Error::Invalid(msg)) => {
                        assert!(msg.contains("minimum of 2") || msg.contains("p must be"), "{msg}")
                    }
                    other => panic!("p={p} {kind:?}: expected Invalid, got {:?}", other.is_ok()),
                }
            }
        }
        // p = 2 is the smallest legal dimension
        let cfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 1 };
        assert!(Sparsifier::new(2, cfg).is_ok());
    }

    #[test]
    fn precond_scheme_is_byte_identical_to_the_default_constructor() {
        // the trait refactor contract: Scheme::Precond reproduces the
        // pre-scheme operator bit for bit, masks and values
        let p = 48; // pads to 64
        let cfg = SparsifyConfig { gamma: 0.2, transform: TransformKind::Hadamard, seed: 31 };
        let old = Sparsifier::new(p, cfg).unwrap();
        let new = Sparsifier::with_scheme(p, cfg, Scheme::Precond).unwrap();
        assert_eq!(new.scheme(), Scheme::Precond);
        assert!(!new.weighted());
        let mut rng = Pcg64::seed(7);
        let x = Mat::from_fn(p, 9, |_, _| rng.normal());
        let a = old.compress_chunk(&x, 5).unwrap();
        let b = new.compress_chunk(&x, 5).unwrap();
        for i in 0..9 {
            assert_eq!(a.col_indices(i), b.col_indices(i));
            for (va, vb) in a.col_values(i).iter().zip(b.col_values(i)) {
                assert_eq!(va.to_bits(), vb.to_bits());
            }
        }
        // and the no-precondition arm matches the uniform scheme
        let uni = Sparsifier::with_scheme(p, cfg, Scheme::Uniform).unwrap();
        let c = old.compress_chunk_no_precondition(&x, 5).unwrap();
        let d = uni.compress_chunk(&x, 5).unwrap();
        for i in 0..9 {
            assert_eq!(c.col_indices(i), d.col_indices(i));
            for (va, vb) in c.col_values(i).iter().zip(d.col_values(i)) {
                assert_eq!(va.to_bits(), vb.to_bits());
            }
        }
    }

    #[test]
    fn precond_masks_pin_the_index_sampler_stream() {
        // pins compress_chunk's mask derivation to the documented stream:
        // Pcg64::seed(seed ^ 0x9E37_79B9_7F4A_7C15).fork(global column),
        // drawn through IndexSampler — the seeded-experiment contract
        let p = 32;
        let seed = 19u64;
        let cfg = SparsifyConfig { gamma: 0.25, transform: TransformKind::Hadamard, seed };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let mut rng = Pcg64::seed(3);
        let x = Mat::from_fn(p, 7, |_, _| rng.normal());
        let start_col = 11usize;
        let chunk = sp.compress_chunk(&x, start_col).unwrap();
        let root = Pcg64::seed(seed ^ 0x9E37_79B9_7F4A_7C15);
        let mut sampler = IndexSampler::new(p);
        let mut expect = vec![0u32; sp.m()];
        for i in 0..7 {
            let mut crng = root.fork((start_col + i) as u64);
            sampler.sample(&mut crng, &mut expect);
            assert_eq!(chunk.col_indices(i), &expect[..], "col {i}");
        }
    }

    #[test]
    fn hybrid_chunks_are_weighted_and_chunk_boundary_invariant() {
        let p = 32;
        let cfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 23 };
        let sp = Sparsifier::with_scheme(p, cfg, Scheme::Hybrid).unwrap();
        assert!(sp.weighted());
        let mut rng = Pcg64::seed(6);
        let x = Mat::from_fn(p, 18, |_, _| rng.normal());
        let whole = sp.compress_chunk(&x, 0).unwrap();
        whole.validate_weighted().unwrap();
        let first = sp.compress_chunk(&x.col_range(0, 7), 0).unwrap();
        let second = sp.compress_chunk(&x.col_range(7, 18), 7).unwrap();
        for i in 0..7 {
            assert_eq!(whole.col_indices(i), first.col_indices(i));
            assert_eq!(whole.col_values(i), first.col_values(i));
        }
        for i in 0..11 {
            assert_eq!(whole.col_indices(7 + i), second.col_indices(i));
            assert_eq!(whole.col_values(7 + i), second.col_values(i));
        }
        // no-precondition entry point is the same path for hybrid
        let again = sp.compress_chunk_no_precondition(&x, 0).unwrap();
        for i in 0..18 {
            assert_eq!(whole.col_indices(i), again.col_indices(i));
            assert_eq!(whole.col_values(i), again.col_values(i));
        }
    }

    #[test]
    fn corollary3_norm_reduction() {
        // With preconditioning, ||w||² ≲ (m/p)(2/η)log(2np/α)||x||² whp.
        let p = 256;
        let n = 50;
        let cfg = SparsifyConfig { gamma: 0.1, transform: TransformKind::Hadamard, seed: 7 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let mut rng = Pcg64::seed(8);
        // adversarial: spiky data
        let x = Mat::from_fn(p, n, |i, j| if i == j % p { 1.0 } else { 0.0 });
        let _ = rng.next_u64();
        let chunk = sp.compress_chunk(&x, 0).unwrap();
        let alpha: f64 = 0.01;
        let bound = sp.gamma() * 2.0 * (2.0 * (n * p) as f64 / alpha).ln();
        for i in 0..n {
            let ratio = chunk.col_norm2(i); // ||x_i||² = 1
            assert!(ratio <= bound, "col {i}: ratio {ratio} > bound {bound}");
        }
    }
}
