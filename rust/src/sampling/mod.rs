//! The paper's compression operator: ROS preconditioning + uniform m-of-p
//! element sampling, fused into a single pass over each chunk.
//!
//! Every sample gets an *independent* sampling matrix `R_i` (m distinct
//! canonical basis vectors, uniform without replacement). Per-column RNG
//! streams are forked from `(seed, global column index)`, so the output
//! is invariant to chunk boundaries and worker scheduling — the
//! coordinator's reproducibility guarantee.

use crate::error::{invalid, Result};
use crate::linalg::Mat;
use crate::rng::Pcg64;
use crate::sparse::SparseChunk;
use crate::transform::{is_pow2, Ros, TransformKind};

/// Configuration of the sparsification front-end.
#[derive(Clone, Copy, Debug)]
pub struct SparsifyConfig {
    /// Compression factor γ = m/p (0 < γ ≤ 1). `m = max(2, round(γ·p))`.
    pub gamma: f64,
    /// Which orthonormal transform `H` to use.
    pub transform: TransformKind,
    /// Root seed for the sign diagonal and all sampling masks.
    pub seed: u64,
}

impl Default for SparsifyConfig {
    fn default() -> Self {
        SparsifyConfig { gamma: 0.1, transform: TransformKind::Hadamard, seed: 0 }
    }
}

/// Draw `m` distinct indices from `{0..p}` uniformly without replacement
/// (partial Fisher–Yates over a caller-provided permutation scratch of
/// length `p`), writing them sorted into `out`.
pub fn sample_indices(rng: &mut Pcg64, p: usize, out: &mut [u32], perm: &mut [u32]) {
    let m = out.len();
    debug_assert!(m <= p && perm.len() == p);
    // reset scratch
    for (i, v) in perm.iter_mut().enumerate() {
        *v = i as u32;
    }
    for i in 0..m {
        let j = i + rng.next_range((p - i) as u32) as usize;
        perm.swap(i, j);
    }
    out.copy_from_slice(&perm[..m]);
    out.sort_unstable();
}

/// The fused precondition+sample operator.
///
/// If the configured transform is Hadamard and `p` is not a power of two,
/// the operator transparently zero-pads to the next power of two
/// (`p_work`), preconditions and samples in the padded space, and reports
/// `p()` = `p_work`. Zero-padding composes with an orthonormal map, so all
/// estimator guarantees hold in the padded space; the adjoint un-pads.
pub struct Sparsifier {
    ros: Ros,
    /// Original ambient dimension (before any padding).
    p_orig: usize,
    /// Working dimension (= p_orig, or next pow2 when padded).
    p_work: usize,
    m: usize,
    seed: u64,
}

impl Sparsifier {
    pub fn new(p: usize, cfg: SparsifyConfig) -> Result<Self> {
        if !(cfg.gamma > 0.0 && cfg.gamma <= 1.0) {
            return invalid(format!("gamma must be in (0,1], got {}", cfg.gamma));
        }
        let p_work = match cfg.transform {
            TransformKind::Hadamard if !is_pow2(p) => p.next_power_of_two(),
            _ => p,
        };
        let m = ((cfg.gamma * p_work as f64).round() as usize).clamp(2, p_work);
        let mut rng = Pcg64::seed(cfg.seed);
        let ros = Ros::new(p_work, cfg.transform, &mut rng)?;
        Ok(Sparsifier { ros, p_orig: p, p_work, m, seed: cfg.seed })
    }

    /// Working (possibly padded) dimension — the `p` of downstream chunks.
    pub fn p(&self) -> usize {
        self.p_work
    }

    /// Original data dimension.
    pub fn p_orig(&self) -> usize {
        self.p_orig
    }

    /// Kept entries per sample.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Effective compression factor m / p_work.
    pub fn gamma(&self) -> f64 {
        self.m as f64 / self.p_work as f64
    }

    pub fn ros(&self) -> &Ros {
        &self.ros
    }

    /// Compress a dense chunk (`p_orig × n`, samples as columns) whose
    /// first column has global index `start_col`. One pass: precondition
    /// each column, sample its mask, store kept values.
    pub fn compress_chunk(&self, x: &Mat, start_col: usize) -> Result<SparseChunk> {
        if x.rows() != self.p_orig {
            return invalid(format!("chunk rows {} != p {}", x.rows(), self.p_orig));
        }
        let n = x.cols();
        let mut out = SparseChunk::with_capacity(self.p_work, self.m, n, start_col);
        let mut buf = vec![0.0f64; self.p_work];
        let mut scratch = vec![0.0f64; self.p_work];
        let mut perm = vec![0u32; self.p_work];
        let mask_root = Pcg64::seed(self.seed ^ 0x9E37_79B9_7F4A_7C15);
        for i in 0..n {
            // pad + precondition
            buf[..self.p_orig].copy_from_slice(x.col(i));
            buf[self.p_orig..].fill(0.0);
            self.ros.apply_col(&mut buf, &mut scratch);
            // per-sample mask from a fork keyed on the global column index
            let mut crng = mask_root.fork((start_col + i) as u64);
            let (idx, vals) = out.col_mut(i);
            sample_indices(&mut crng, self.p_work, idx, &mut perm);
            for (v, &j) in vals.iter_mut().zip(idx.iter()) {
                *v = buf[j as usize];
            }
        }
        Ok(out)
    }

    /// Sparsify *without* preconditioning (the paper's "no precondition"
    /// ablation arm — Figs 7/10, Table I/III). Masks are drawn from the
    /// same streams as [`compress_chunk`](Self::compress_chunk).
    pub fn compress_chunk_no_precondition(&self, x: &Mat, start_col: usize) -> Result<SparseChunk> {
        if x.rows() != self.p_orig {
            return invalid(format!("chunk rows {} != p {}", x.rows(), self.p_orig));
        }
        let n = x.cols();
        let mut out = SparseChunk::with_capacity(self.p_work, self.m, n, start_col);
        let mut perm = vec![0u32; self.p_work];
        let mask_root = Pcg64::seed(self.seed ^ 0x9E37_79B9_7F4A_7C15);
        for i in 0..n {
            let col = x.col(i);
            let mut crng = mask_root.fork((start_col + i) as u64);
            let (idx, vals) = out.col_mut(i);
            sample_indices(&mut crng, self.p_work, idx, &mut perm);
            for (v, &j) in vals.iter_mut().zip(idx.iter()) {
                *v = if (j as usize) < self.p_orig { col[j as usize] } else { 0.0 };
            }
        }
        Ok(out)
    }

    /// Un-mix a matrix of centers/estimates from the preconditioned domain
    /// back to the original coordinates (paper Eq. 32), dropping padding.
    pub fn unmix(&self, mu_precond: &Mat) -> Mat {
        assert_eq!(mu_precond.rows(), self.p_work);
        let mut y = mu_precond.clone();
        self.ros.adjoint_inplace(&mut y);
        if self.p_work == self.p_orig {
            y
        } else {
            let mut out = Mat::zeros(self.p_orig, y.cols());
            for j in 0..y.cols() {
                out.col_mut(j).copy_from_slice(&y.col(j)[..self.p_orig]);
            }
            out
        }
    }

    /// Drop padding rows only (no adjoint transform) — the center
    /// recovery for the *no-preconditioning* ablation arm.
    pub fn truncate(&self, mat: &Mat) -> Mat {
        assert_eq!(mat.rows(), self.p_work);
        if self.p_work == self.p_orig {
            return mat.clone();
        }
        let mut out = Mat::zeros(self.p_orig, mat.cols());
        for j in 0..mat.cols() {
            out.col_mut(j).copy_from_slice(&mat.col(j)[..self.p_orig]);
        }
        out
    }

    /// Precondition a dense chunk (pad + HD), without sampling — used by
    /// oracle computations in tests/experiments.
    pub fn precondition_dense(&self, x: &Mat) -> Mat {
        assert_eq!(x.rows(), self.p_orig);
        let mut out = Mat::zeros(self.p_work, x.cols());
        let mut scratch = vec![0.0; self.p_work];
        for j in 0..x.cols() {
            out.col_mut(j)[..self.p_orig].copy_from_slice(x.col(j));
            self.ros.apply_col(out.col_mut(j), &mut scratch);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::forall;

    #[test]
    fn sample_indices_properties() {
        forall("sample_indices", 50, |g| {
            let p = g.int(2, 200) as usize;
            let m = g.int(1, p as i64) as usize;
            let mut rng = Pcg64::seed(g.int(0, 1 << 40) as u64);
            let mut out = vec![0u32; m];
            let mut perm = vec![0u32; p];
            sample_indices(&mut rng, p, &mut out, &mut perm);
            for w in out.windows(2) {
                assert!(w[0] < w[1], "sorted+distinct violated: {out:?}");
            }
            assert!(*out.last().unwrap() < p as u32);
        });
    }

    #[test]
    fn sample_indices_uniform_marginals() {
        // Lemma B5: P[keep coordinate j] = m/p for every j.
        let (p, m, trials) = (32usize, 8usize, 40_000usize);
        let mut rng = Pcg64::seed(42);
        let mut counts = vec![0usize; p];
        let mut out = vec![0u32; m];
        let mut perm = vec![0u32; p];
        for _ in 0..trials {
            sample_indices(&mut rng, p, &mut out, &mut perm);
            for &j in &out {
                counts[j as usize] += 1;
            }
        }
        let expect = trials as f64 * m as f64 / p as f64;
        for &c in &counts {
            assert!(
                (c as f64 - expect).abs() < 5.0 * (expect * (1.0 - m as f64 / p as f64)).sqrt(),
                "count {c} vs expected {expect}"
            );
        }
    }

    #[test]
    fn compress_chunk_keeps_preconditioned_values() {
        let p = 64;
        let cfg = SparsifyConfig { gamma: 0.25, transform: TransformKind::Hadamard, seed: 5 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let mut rng = Pcg64::seed(9);
        let x = Mat::from_fn(p, 10, |_, _| rng.normal());
        let y = sp.precondition_dense(&x);
        let chunk = sp.compress_chunk(&x, 0).unwrap();
        chunk.validate().unwrap();
        assert_eq!(chunk.m(), 16);
        for i in 0..10 {
            for (idx, val) in chunk.col_indices(i).iter().zip(chunk.col_values(i)) {
                assert!((val - y.get(*idx as usize, i)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn chunk_boundaries_do_not_change_output() {
        let p = 32;
        let cfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 11 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let mut rng = Pcg64::seed(13);
        let x = Mat::from_fn(p, 20, |_, _| rng.normal());
        let whole = sp.compress_chunk(&x, 0).unwrap();
        let first = sp.compress_chunk(&x.col_range(0, 12), 0).unwrap();
        let second = sp.compress_chunk(&x.col_range(12, 20), 12).unwrap();
        for i in 0..12 {
            assert_eq!(whole.col_indices(i), first.col_indices(i));
            assert_eq!(whole.col_values(i), first.col_values(i));
        }
        for i in 0..8 {
            assert_eq!(whole.col_indices(12 + i), second.col_indices(i));
            assert_eq!(whole.col_values(12 + i), second.col_values(i));
        }
    }

    #[test]
    fn padding_for_non_pow2_hadamard() {
        let p = 100;
        let cfg = SparsifyConfig { gamma: 0.25, transform: TransformKind::Hadamard, seed: 1 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        assert_eq!(sp.p(), 128);
        assert_eq!(sp.p_orig(), 100);
        assert_eq!(sp.m(), 32);
        let mut rng = Pcg64::seed(2);
        let x = Mat::from_fn(p, 4, |_, _| rng.normal());
        let chunk = sp.compress_chunk(&x, 0).unwrap();
        assert_eq!(chunk.p(), 128);
        // unmix of a preconditioned dense chunk recovers the original
        let y = sp.precondition_dense(&x);
        let back = sp.unmix(&y);
        assert!((back.sub(&x)).max_abs() < 1e-9);
    }

    #[test]
    fn no_precondition_keeps_raw_values() {
        let p = 16;
        let cfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 3 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let mut rng = Pcg64::seed(4);
        let x = Mat::from_fn(p, 6, |_, _| rng.normal());
        let chunk = sp.compress_chunk_no_precondition(&x, 0).unwrap();
        for i in 0..6 {
            for (idx, val) in chunk.col_indices(i).iter().zip(chunk.col_values(i)) {
                assert_eq!(*val, x.get(*idx as usize, i));
            }
        }
    }

    #[test]
    fn masks_match_between_precond_and_not() {
        // Both arms of the ablation must see identical masks so the
        // comparison isolates the preconditioner.
        let p = 32;
        let cfg = SparsifyConfig { gamma: 0.25, transform: TransformKind::Hadamard, seed: 21 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let mut rng = Pcg64::seed(22);
        let x = Mat::from_fn(p, 5, |_, _| rng.normal());
        let a = sp.compress_chunk(&x, 0).unwrap();
        let b = sp.compress_chunk_no_precondition(&x, 0).unwrap();
        for i in 0..5 {
            assert_eq!(a.col_indices(i), b.col_indices(i));
        }
    }

    #[test]
    fn corollary3_norm_reduction() {
        // With preconditioning, ||w||² ≲ (m/p)(2/η)log(2np/α)||x||² whp.
        let p = 256;
        let n = 50;
        let cfg = SparsifyConfig { gamma: 0.1, transform: TransformKind::Hadamard, seed: 7 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let mut rng = Pcg64::seed(8);
        // adversarial: spiky data
        let x = Mat::from_fn(p, n, |i, j| if i == j % p { 1.0 } else { 0.0 });
        let _ = rng.next_u64();
        let chunk = sp.compress_chunk(&x, 0).unwrap();
        let alpha: f64 = 0.01;
        let bound = sp.gamma() * 2.0 * (2.0 * (n * p) as f64 / alpha).ln();
        for i in 0..n {
            let ratio = chunk.col_norm2(i); // ||x_i||² = 1
            assert!(ratio <= bound, "col {i}: ratio {ratio} > bound {bound}");
        }
    }
}
