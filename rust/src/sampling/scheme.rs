//! Pluggable per-column element-sampling schemes.
//!
//! The paper's compression operator is *one point* in a family of
//! element-sampling schemes: precondition-then-sample-uniformly. Its
//! abstract positions that choice against "related sampling approaches" —
//! canonically the hybrid-(ℓ1,ℓ2) element sampling of Kundu, Drineas &
//! Magdon-Ismail (arXiv:1503.00547). This module makes the scheme a
//! first-class axis so those comparisons are reproducible:
//!
//! * [`PreconditionedUniform`] — the paper's operator: ROS precondition,
//!   then keep `m` of `p` entries uniformly without replacement. Raw
//!   (unweighted) values; the Thm 4/6 estimators apply their uniform
//!   rescales downstream. **Byte-identical** to the pre-trait
//!   implementation (asserted in tests).
//! * [`UniformNoPrecondition`] — the same uniform masks on the raw data
//!   (the paper's ablation arm, Figs 7/10, Tables I/III). Same mask
//!   streams as [`PreconditionedUniform`], so ablations isolate the
//!   preconditioner.
//! * [`HybridL1L2`] — per-column importance sampling *with replacement*:
//!   `m` i.i.d. draws from `q_j ∝ λ·|y_j|/‖y‖₁ + (1−λ)·y_j²/‖y‖₂²`
//!   (the hybrid-(ℓ1,ℓ2) distribution with an ℓ1 mixing floor `λ`),
//!   each kept slot storing the inverse-probability-scaled value
//!   `y_j/(m·q_j)`. The resulting column is an exactly **unbiased
//!   sketch** of `y`, and the cross-slot covariance calibration below
//!   keeps the Thm 6-style estimate exactly unbiased too.
//!
//! # Weighted-scheme calibration (why the consumers stay unchanged)
//!
//! Downstream kernels never branch on the scheme: weights live in the
//! chunk values, and the estimators only swap two scalar constants.
//! With `v_i = Σ_l u_l e_{j_l}` the scatter-add of column `i`'s slots,
//! `G = Σ_i v_i v_iᵀ` the raw scatter and `S` the diagonal of per-slot
//! squares (`S_jj = Σ slots u²` — exactly what
//! [`ScatterDiag`](crate::estimators::ScatterDiag) accumulates), the
//! hybrid estimator is
//!
//! ```text
//! Ĉ = m/((m−1)·n) · (G − diag(S))
//! ```
//!
//! which is **exactly unbiased** for `C_emp = (1/n) Σ y_i y_iᵀ`: every
//! ordered cross-slot pair `(a ≠ b)` contributes
//! `E[u_a u_b 1{j_a=j, j_b=k}] = y_j y_k / m²` and there are `m(m−1)` of
//! them, for *every* cell including the diagonal — while `G − diag(S)`
//! is precisely the cross-slot part of `G`. (A fixed-size
//! *without*-replacement design cannot be calibrated this way: the two
//! moment conditions on a single per-entry weight are jointly satisfiable
//! only at the uniform design — which is exactly the "certain benefits"
//! contrast the source paper draws. See `rust/ARCHITECTURE.md`
//! §Sampling schemes for the derivation.)
//!
//! Mean estimation under the hybrid scheme needs scale `1` (not `p/m`):
//! `E[v_i] = y_i` already. [`Scheme::weighted`] drives both calibrations
//! through `FitPlan`.

use crate::error::{invalid, Result};
use crate::rng::Pcg64;

use super::IndexSampler;

/// Default ℓ1 mixing floor `λ` of [`HybridL1L2`] — small but positive, as
/// recommended by Kundu et al. (the ℓ1 term guards the variance of
/// inverse-probability weights on heavy-tailed columns).
pub const DEFAULT_HYBRID_L1_MIX: f64 = 0.1;

/// A per-column element-selection law: given one (possibly
/// preconditioned, zero-padded) column, choose which `m` slots to keep
/// and what (possibly importance-weighted) values to store.
///
/// Implementations must be deterministic functions of `(y, crng)` — the
/// caller forks `crng` from `(seed, global column index)`, which is what
/// keeps compressed chunks independent of chunk boundaries and worker
/// scheduling (the coordinator's reproducibility contract).
pub trait SamplingScheme: Send + Sync {
    /// Stable lowercase name (CLI `--scheme`, store manifests).
    fn name(&self) -> &'static str;

    /// Whether columns are ROS-preconditioned before sampling.
    fn preconditions(&self) -> bool;

    /// Whether stored values are importance-weighted with-replacement
    /// slots (duplicate indices allowed; consumers must use the
    /// weighted estimator calibration and mean scale `1`).
    fn weighted(&self) -> bool;

    /// Fill one column's mask (`idx`) and stored values (`vals`), both of
    /// length `m`, from the length-`p` column `y`.
    ///
    /// * `sampler` — shared O(m) uniform mask sampler (uniform schemes
    ///   draw through it so their RNG stream stays byte-identical to the
    ///   pre-trait implementation).
    /// * `scratch` — caller-provided length-`p` workspace (cumulative
    ///   weights for the hybrid scheme; uniform schemes ignore it).
    ///
    /// On return `idx` is sorted ascending (strictly for uniform schemes,
    /// non-strictly — duplicates allowed — for weighted ones) and every
    /// index is `< p`.
    fn sample_column(
        &self,
        y: &[f64],
        crng: &mut Pcg64,
        sampler: &mut IndexSampler,
        idx: &mut [u32],
        vals: &mut [f64],
        scratch: &mut [f64],
    );
}

/// Shared body of both uniform schemes: draw the uniform
/// without-replacement mask through [`IndexSampler`] (byte-identical RNG
/// stream to the pre-trait `compress_chunk` loop) and store raw values.
fn uniform_sample_column(
    y: &[f64],
    crng: &mut Pcg64,
    sampler: &mut IndexSampler,
    idx: &mut [u32],
    vals: &mut [f64],
) {
    sampler.sample(crng, idx);
    for (v, &j) in vals.iter_mut().zip(idx.iter()) {
        *v = y[j as usize];
    }
}

/// The paper's operator: ROS preconditioning + uniform `m`-of-`p`
/// element sampling without replacement, raw values.
#[derive(Clone, Copy, Debug, Default)]
pub struct PreconditionedUniform;

impl SamplingScheme for PreconditionedUniform {
    fn name(&self) -> &'static str {
        "precond"
    }

    fn preconditions(&self) -> bool {
        true
    }

    fn weighted(&self) -> bool {
        false
    }

    fn sample_column(
        &self,
        y: &[f64],
        crng: &mut Pcg64,
        sampler: &mut IndexSampler,
        idx: &mut [u32],
        vals: &mut [f64],
        _scratch: &mut [f64],
    ) {
        uniform_sample_column(y, crng, sampler, idx, vals);
    }
}

/// Uniform element sampling of the **raw** data (no ROS) — the paper's
/// ablation arm. Masks are drawn from the same per-column streams as
/// [`PreconditionedUniform`], so the two arms differ only in the
/// preconditioner.
#[derive(Clone, Copy, Debug, Default)]
pub struct UniformNoPrecondition;

impl SamplingScheme for UniformNoPrecondition {
    fn name(&self) -> &'static str {
        "uniform"
    }

    fn preconditions(&self) -> bool {
        false
    }

    fn weighted(&self) -> bool {
        false
    }

    fn sample_column(
        &self,
        y: &[f64],
        crng: &mut Pcg64,
        sampler: &mut IndexSampler,
        idx: &mut [u32],
        vals: &mut [f64],
        _scratch: &mut [f64],
    ) {
        uniform_sample_column(y, crng, sampler, idx, vals);
    }
}

/// Hybrid-(ℓ1,ℓ2) element sampling (Kundu, Drineas & Magdon-Ismail,
/// arXiv:1503.00547), per column, with replacement:
///
/// `m` i.i.d. draws from `q_j ∝ λ·|y_j|/‖y‖₁ + (1−λ)·y_j²/‖y‖₂²`, each
/// slot storing `y_j/(m·q_j)`. The scatter-add of a column's slots is an
/// exactly unbiased sketch of `y`, and the cross-slot calibration (module
/// docs) keeps the covariance estimate exactly unbiased. Slots are
/// stored sorted by index with duplicates allowed.
///
/// Zero columns fall back to the uniform mask (all stored values are
/// zero either way, and the fallback keeps the per-column RNG cost
/// bounded).
#[derive(Clone, Copy, Debug)]
pub struct HybridL1L2 {
    /// ℓ1 mixing floor `λ ∈ [0, 1]` (`0` = pure ℓ2, `1` = pure ℓ1).
    l1_mix: f64,
}

impl HybridL1L2 {
    /// Hybrid scheme with mixing floor `λ` (clamped to `[0, 1]`), for
    /// driving [`sample_column`](SamplingScheme::sample_column) directly
    /// (library use, property tests). The `Sparsifier`/`FitPlan`/store
    /// pipeline resolves [`Scheme::Hybrid`] to the shared instance at
    /// [`DEFAULT_HYBRID_L1_MIX`] — a custom `λ` is **not** threadable
    /// through the pipeline (the manifest records only the scheme name),
    /// by design: one canonical hybrid arm keeps every seeded
    /// scheme-comparison reproducible from the scheme name alone.
    pub fn new(l1_mix: f64) -> Self {
        HybridL1L2 { l1_mix: l1_mix.clamp(0.0, 1.0) }
    }

    /// The configured ℓ1 mixing floor.
    pub fn l1_mix(&self) -> f64 {
        self.l1_mix
    }
}

impl Default for HybridL1L2 {
    fn default() -> Self {
        HybridL1L2 { l1_mix: DEFAULT_HYBRID_L1_MIX }
    }
}

impl SamplingScheme for HybridL1L2 {
    fn name(&self) -> &'static str {
        "hybrid"
    }

    fn preconditions(&self) -> bool {
        false
    }

    fn weighted(&self) -> bool {
        true
    }

    fn sample_column(
        &self,
        y: &[f64],
        crng: &mut Pcg64,
        sampler: &mut IndexSampler,
        idx: &mut [u32],
        vals: &mut [f64],
        scratch: &mut [f64],
    ) {
        let p = y.len();
        let m = idx.len();
        debug_assert_eq!(scratch.len(), p);
        let mut l1 = 0.0f64;
        let mut l2 = 0.0f64;
        for &v in y {
            l1 += v.abs();
            l2 += v * v;
        }
        if !(l1 > 0.0 && l2 > 0.0 && l1.is_finite() && l2.is_finite()) {
            // degenerate column — all zero (any mask is correct, values
            // are 0) or non-finite (importance weights are undefined):
            // fall back to the uniform mask with the raw values, exactly
            // what the uniform schemes would store
            uniform_sample_column(y, crng, sampler, idx, vals);
            return;
        }
        // cumulative un-normalized hybrid weights w_j = λ|y_j|/‖y‖₁ +
        // (1−λ)y_j²/‖y‖₂² (so Σ w_j = 1 up to rounding; we sample
        // against the actual running total, never assuming it is 1)
        let (la, lb) = (self.l1_mix / l1, (1.0 - self.l1_mix) / l2);
        let weight = |v: f64| la * v.abs() + lb * v * v;
        let mut total = 0.0f64;
        for (c, &v) in scratch.iter_mut().zip(y.iter()) {
            total += weight(v);
            *c = total;
        }
        // m i.i.d. draws, kept as separate slots, drawn straight into
        // `idx` (no per-column heap allocation on the compress hot path)
        for slot in idx.iter_mut() {
            let u = crng.next_f64() * total;
            let mut j = scratch.partition_point(|&c| c <= u).min(p - 1);
            // a zero-weight index is unreachable except through a
            // floating-point boundary tie; walk to the nearest positive
            // weight (total > 0 guarantees one exists)
            let mut wj = weight(y[j]);
            while wj <= 0.0 && j > 0 {
                j -= 1;
                wj = weight(y[j]);
            }
            while wj <= 0.0 && j + 1 < p {
                j += 1;
                wj = weight(y[j]);
            }
            debug_assert!(wj > 0.0, "hybrid draw landed on zero total mass");
            *slot = j as u32;
        }
        // sorted by index, duplicates allowed. A slot's value
        // `y_j/(m·q_j)` is a pure function of its index, so the values
        // are filled after the sort — equal indices carry bitwise-equal
        // values, making the draw order immaterial.
        idx.sort_unstable();
        for (v, &j) in vals.iter_mut().zip(idx.iter()) {
            let yj = y[j as usize];
            *v = yj * total / (m as f64 * weight(yj));
        }
    }
}

/// Nameable scheme selector — the configuration-level handle used by
/// [`Sparsifier::with_scheme`](super::Sparsifier::with_scheme), the CLI
/// (`--scheme`), and store manifests. Resolves to a shared
/// [`SamplingScheme`] instance via [`instance`](Scheme::instance).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    /// [`PreconditionedUniform`] — the paper's operator (default).
    Precond,
    /// [`UniformNoPrecondition`] — uniform masks on raw data.
    Uniform,
    /// [`HybridL1L2`] — weighted hybrid-(ℓ1,ℓ2) sampling at
    /// [`DEFAULT_HYBRID_L1_MIX`].
    Hybrid,
}

static PRECOND_INSTANCE: PreconditionedUniform = PreconditionedUniform;
static UNIFORM_INSTANCE: UniformNoPrecondition = UniformNoPrecondition;
static HYBRID_INSTANCE: HybridL1L2 = HybridL1L2 { l1_mix: DEFAULT_HYBRID_L1_MIX };

impl Scheme {
    /// The shared implementation instance for this selector.
    pub fn instance(self) -> &'static dyn SamplingScheme {
        match self {
            Scheme::Precond => &PRECOND_INSTANCE,
            Scheme::Uniform => &UNIFORM_INSTANCE,
            Scheme::Hybrid => &HYBRID_INSTANCE,
        }
    }

    /// Stable lowercase name (CLI flags, store manifests).
    pub fn name(self) -> &'static str {
        self.instance().name()
    }

    /// Inverse of [`name`](Self::name).
    pub fn parse(s: &str) -> Result<Scheme> {
        Ok(match s {
            "precond" => Scheme::Precond,
            "uniform" => Scheme::Uniform,
            "hybrid" => Scheme::Hybrid,
            other => {
                return invalid(format!(
                    "unknown sampling scheme {other:?} (want precond|uniform|hybrid)"
                ))
            }
        })
    }

    /// Whether this scheme ROS-preconditions before sampling.
    pub fn preconditions(self) -> bool {
        self.instance().preconditions()
    }

    /// Whether this scheme stores importance-weighted with-replacement
    /// slots (see the module docs for the estimator calibration).
    pub fn weighted(self) -> bool {
        self.instance().weighted()
    }
}

impl Default for Scheme {
    fn default() -> Self {
        Scheme::Precond
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;

    #[test]
    fn scheme_names_roundtrip() {
        for s in [Scheme::Precond, Scheme::Uniform, Scheme::Hybrid] {
            assert_eq!(Scheme::parse(s.name()).unwrap(), s);
        }
        assert!(Scheme::parse("nope").is_err());
        assert_eq!(Scheme::default(), Scheme::Precond);
        assert!(Scheme::Precond.preconditions());
        assert!(!Scheme::Uniform.preconditions());
        assert!(!Scheme::Hybrid.preconditions());
        assert!(Scheme::Hybrid.weighted());
        assert!(!Scheme::Precond.weighted());
    }

    #[test]
    fn uniform_schemes_replicate_the_index_sampler_stream() {
        // the trait refactor must not change a single RNG draw: the
        // uniform schemes' masks are the IndexSampler stream, bit for bit
        let (p, m) = (64usize, 16usize);
        let mut rng = Pcg64::seed(3);
        let y: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
        for scheme in [Scheme::Precond, Scheme::Uniform] {
            let mut direct = vec![0u32; m];
            let mut via_trait = vec![0u32; m];
            let mut vals = vec![0.0f64; m];
            let mut scratch = vec![0.0f64; p];
            for col in 0..5u64 {
                let root = Pcg64::seed(9 ^ 0x9E37_79B9_7F4A_7C15);
                let mut sampler_a = IndexSampler::new(p);
                let mut sampler_b = IndexSampler::new(p);
                let mut crng_a = root.fork(col);
                let mut crng_b = root.fork(col);
                sampler_a.sample(&mut crng_a, &mut direct);
                scheme.instance().sample_column(
                    &y,
                    &mut crng_b,
                    &mut sampler_b,
                    &mut via_trait,
                    &mut vals,
                    &mut scratch,
                );
                assert_eq!(direct, via_trait, "scheme {} col {col}", scheme.name());
                for (v, &j) in vals.iter().zip(via_trait.iter()) {
                    assert_eq!(v.to_bits(), y[j as usize].to_bits());
                }
            }
        }
    }

    #[test]
    fn hybrid_slots_are_sorted_in_range_and_weighted() {
        let (p, m) = (32usize, 12usize);
        let mut rng = Pcg64::seed(7);
        let y: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
        let scheme = HybridL1L2::default();
        let mut sampler = IndexSampler::new(p);
        let mut idx = vec![0u32; m];
        let mut vals = vec![0.0f64; m];
        let mut scratch = vec![0.0f64; p];
        for col in 0..20u64 {
            let mut crng = Pcg64::seed(5).fork(col);
            scheme.sample_column(&y, &mut crng, &mut sampler, &mut idx, &mut vals, &mut scratch);
            for w in idx.windows(2) {
                assert!(w[0] <= w[1], "non-decreasing violated: {idx:?}");
            }
            assert!(*idx.last().unwrap() < p as u32);
            for (&j, &v) in idx.iter().zip(&vals) {
                // slot value has the sign of (and is proportional to) y_j
                assert!(v * y[j as usize] > 0.0 || y[j as usize] == 0.0);
                assert!(v.is_finite());
            }
        }
    }

    #[test]
    fn hybrid_zero_column_falls_back_to_uniform_mask() {
        let (p, m) = (16usize, 4usize);
        let y = vec![0.0f64; p];
        let scheme = HybridL1L2::default();
        let mut sampler = IndexSampler::new(p);
        let mut idx = vec![0u32; m];
        let mut vals = vec![1.0f64; m];
        let mut scratch = vec![0.0f64; p];
        let mut crng = Pcg64::seed(11).fork(0);
        scheme.sample_column(&y, &mut crng, &mut sampler, &mut idx, &mut vals, &mut scratch);
        for w in idx.windows(2) {
            assert!(w[0] < w[1], "fallback mask must be distinct + sorted");
        }
        assert!(vals.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn hybrid_sketch_is_unbiased_for_the_column() {
        // Monte-Carlo: the scatter-add of a column's slots averages to
        // the column itself — E[v] = y, the Kundu et al. sketch property.
        // Tolerance is self-calibrated from the per-coordinate MC
        // standard error, so the test does not depend on hand-tuned
        // constants.
        let (p, m, trials) = (16usize, 6usize, 20_000usize);
        let mut rng = Pcg64::seed(21);
        let y: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
        let scheme = HybridL1L2::new(0.2);
        let mut sampler = IndexSampler::new(p);
        let mut idx = vec![0u32; m];
        let mut vals = vec![0.0f64; m];
        let mut scratch = vec![0.0f64; p];
        let mut sum = vec![0.0f64; p];
        let mut sumsq = vec![0.0f64; p];
        let root = Pcg64::seed(1234);
        let mut v = vec![0.0f64; p];
        for t in 0..trials {
            let mut crng = root.fork(t as u64);
            scheme.sample_column(&y, &mut crng, &mut sampler, &mut idx, &mut vals, &mut scratch);
            v.iter_mut().for_each(|x| *x = 0.0);
            for (&j, &val) in idx.iter().zip(&vals) {
                v[j as usize] += val;
            }
            for j in 0..p {
                sum[j] += v[j];
                sumsq[j] += v[j] * v[j];
            }
        }
        let tf = trials as f64;
        for j in 0..p {
            let mean = sum[j] / tf;
            let var = (sumsq[j] / tf - mean * mean).max(0.0);
            let se = (var / tf).sqrt();
            assert!(
                (mean - y[j]).abs() <= 6.0 * se + 1e-9,
                "coord {j}: mean {mean} vs y {} (se {se})",
                y[j]
            );
        }
    }

    #[test]
    fn hybrid_l2_bias_concentrates_mass_on_heavy_coordinates() {
        // With one dominant coordinate and small λ, the hybrid draws must
        // hit it far more often than uniform sampling would (that is the
        // point of importance sampling).
        let (p, m, trials) = (32usize, 4usize, 4000usize);
        let mut y = vec![0.05f64; p];
        y[7] = 10.0;
        let scheme = HybridL1L2::new(0.1);
        let mut sampler = IndexSampler::new(p);
        let mut idx = vec![0u32; m];
        let mut vals = vec![0.0f64; m];
        let mut scratch = vec![0.0f64; p];
        let mut hits = 0usize;
        let root = Pcg64::seed(77);
        for t in 0..trials {
            let mut crng = root.fork(t as u64);
            scheme.sample_column(&y, &mut crng, &mut sampler, &mut idx, &mut vals, &mut scratch);
            hits += idx.iter().filter(|&&j| j == 7).count();
        }
        let rate = hits as f64 / (trials * m) as f64;
        // uniform would give 1/32 ≈ 0.031; ℓ2-dominated q gives ≈ 0.95
        assert!(rate > 0.5, "heavy coordinate hit rate {rate} too low");
    }

    #[test]
    fn hybrid_is_deterministic_per_column_stream() {
        let (p, m) = (24usize, 8usize);
        let mut rng = Pcg64::seed(2);
        let y: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
        let scheme = HybridL1L2::default();
        let run = |seed: u64| {
            let mut sampler = IndexSampler::new(p);
            let mut idx = vec![0u32; m];
            let mut vals = vec![0.0f64; m];
            let mut scratch = vec![0.0f64; p];
            let mut crng = Pcg64::seed(seed).fork(3);
            scheme.sample_column(&y, &mut crng, &mut sampler, &mut idx, &mut vals, &mut scratch);
            (idx, vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>())
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9).0, run(10).0);
    }

    #[test]
    fn hybrid_scheme_through_sparsifier_matches_direct_sampling() {
        // the Sparsifier plumbing must feed the scheme the padded raw
        // column and the per-column fork — cross-check against a direct
        // call
        use crate::sampling::{Sparsifier, SparsifyConfig};
        use crate::transform::TransformKind;
        let p = 24usize; // pads to 32 under Hadamard
        let cfg = SparsifyConfig { gamma: 0.25, transform: TransformKind::Hadamard, seed: 13 };
        let sp = Sparsifier::with_scheme(p, cfg, Scheme::Hybrid).unwrap();
        assert_eq!(sp.p(), 32);
        let mut rng = Pcg64::seed(4);
        let x = Mat::from_fn(p, 6, |_, _| rng.normal());
        let chunk = sp.compress_chunk(&x, 3).unwrap();
        chunk.validate_weighted().unwrap();
        let scheme = HybridL1L2::default();
        let mut sampler = IndexSampler::new(sp.p());
        let mut idx = vec![0u32; sp.m()];
        let mut vals = vec![0.0f64; sp.m()];
        let mut scratch = vec![0.0f64; sp.p()];
        let root = Pcg64::seed(13 ^ 0x9E37_79B9_7F4A_7C15);
        for i in 0..6 {
            let mut y = vec![0.0f64; sp.p()];
            y[..p].copy_from_slice(x.col(i));
            let mut crng = root.fork((3 + i) as u64);
            scheme.sample_column(&y, &mut crng, &mut sampler, &mut idx, &mut vals, &mut scratch);
            assert_eq!(chunk.col_indices(i), &idx[..]);
            for (a, b) in chunk.col_values(i).iter().zip(&vals) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }
}
