//! Theorem 7: conditioning of the sparsified center-update system.
//!
//! `H_k = (p/m)(1/n_k) Σ_{i∈I_k} R_i R_iᵀ` is diagonal; its `j`-th entry
//! counts how often coordinate `j` was sampled within cluster `k`, scaled
//! by `p/(m·n_k)`. Theorem 7 bounds `‖H_k − I‖₂` — i.e. how close the
//! entry-wise averaging of Eq. (39) is to a plain average.

use crate::error::{invalid, Result};
use crate::sparse::SparseChunk;

/// Streaming accumulator for the per-coordinate sampling counts of one
/// cluster (or of the whole stream).
#[derive(Clone, Debug)]
pub struct HkAccumulator {
    p: usize,
    m: usize,
    counts: Vec<u64>,
    n: usize,
}

impl HkAccumulator {
    /// Fresh accumulator for chunks of shape `(p, m)`.
    pub fn new(p: usize, m: usize) -> Self {
        HkAccumulator { p, m, counts: vec![0; p], n: 0 }
    }

    /// Count every column of a chunk.
    pub fn accumulate(&mut self, chunk: &SparseChunk) {
        assert_eq!(chunk.p(), self.p);
        for i in 0..chunk.n() {
            for &j in chunk.col_indices(i) {
                self.counts[j as usize] += 1;
            }
        }
        self.n += chunk.n();
    }

    /// Count a subset of columns (the members of one cluster).
    pub fn accumulate_subset(&mut self, chunk: &SparseChunk, members: &[usize]) {
        assert_eq!(chunk.p(), self.p);
        for &i in members {
            for &j in chunk.col_indices(i) {
                self.counts[j as usize] += 1;
            }
        }
        self.n += members.len();
    }

    /// Samples counted so far.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Merge a partner accumulator (distributed reduction). Integer
    /// counts, so the fold is exactly associative and commutative —
    /// fails with [`Error::Invalid`](crate::error::Error::Invalid) on a
    /// shape mismatch instead of silently mixing count spaces.
    pub fn merge(&mut self, other: &HkAccumulator) -> Result<()> {
        if (self.p, self.m) != (other.p, other.m) {
            return invalid(format!(
                "cannot merge HkAccumulator (p={}, m={}) with (p={}, m={})",
                self.p, self.m, other.p, other.m
            ));
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.n += other.n;
        Ok(())
    }

    /// `(p, m)` the accumulator was built for.
    pub(crate) fn shape(&self) -> (usize, usize) {
        (self.p, self.m)
    }

    /// Raw per-coordinate sampling counts — the serializable state.
    pub(crate) fn counts_raw(&self) -> &[u64] {
        &self.counts
    }

    /// Rebuild from serialized state (the `distributed` codec).
    pub(crate) fn from_raw(p: usize, m: usize, counts: Vec<u64>, n: usize) -> Self {
        assert_eq!(counts.len(), p, "hk state length mismatch");
        HkAccumulator { p, m, counts, n }
    }

    /// Diagonal of `H_k` (Eq. 41).
    pub fn hk_diagonal(&self) -> Vec<f64> {
        assert!(self.n > 0);
        let scale = self.p as f64 / (self.m as f64 * self.n as f64);
        self.counts.iter().map(|&c| c as f64 * scale).collect()
    }

    /// `‖H_k − I‖₂` — exact for a diagonal matrix: `max_j |H_jj − 1|`.
    pub fn deviation_norm(&self) -> f64 {
        self.hk_diagonal().iter().map(|d| (d - 1.0).abs()).fold(0.0, f64::max)
    }

    /// Coordinates never sampled (Eq. 39's `n_k^{(j)} = 0` degenerate set).
    pub fn unseen_coordinates(&self) -> usize {
        self.counts.iter().filter(|&&c| c == 0).count()
    }

    /// Theorem 7 bound: `t` such that `‖H_k − I‖₂ ≤ t` w.p. ≥ 1 − δ₃,
    /// given `n_k` member samples (Eq. 43). Delegates to the shared
    /// [`center_error_bound`](crate::estimators::center_error_bound)
    /// inversion, which the K-means fit also evaluates per iteration.
    pub fn t_for_delta(p: usize, m: usize, n_k: usize, delta3: f64) -> f64 {
        crate::estimators::center_error_bound(p, m, n_k, delta3)
    }

    /// Failure probability δ₃ at deviation `t` (Eq. 43, forward direction).
    pub fn delta_for_t(p: usize, m: usize, n_k: usize, t: f64) -> f64 {
        let r = p as f64 / m as f64;
        let nk = n_k as f64;
        p as f64 * (-(nk * t * t) / 2.0 / ((r - 1.0) + (r + 1.0) * t / 3.0)).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;
    use crate::sampling::{Sparsifier, SparsifyConfig};
    use crate::transform::TransformKind;

    fn chunk(p: usize, gamma: f64, n: usize, seed: u64) -> (Sparsifier, SparseChunk) {
        let cfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let mut rng = Pcg64::seed(seed ^ 0xAB);
        let x = Mat::from_fn(p, n, |_, _| rng.normal());
        let c = sp.compress_chunk(&x, 0).unwrap();
        (sp, c)
    }

    #[test]
    fn hk_converges_to_identity() {
        let (sp, c) = chunk(64, 0.25, 20_000, 3);
        let mut acc = HkAccumulator::new(sp.p(), sp.m());
        acc.accumulate(&c);
        assert!(acc.deviation_norm() < 0.1, "dev {}", acc.deviation_norm());
        assert_eq!(acc.unseen_coordinates(), 0);
    }

    #[test]
    fn hk_mean_is_one() {
        // Σ_j counts_j = m·n exactly, so the average diagonal is exactly 1.
        let (sp, c) = chunk(32, 0.3, 500, 5);
        let mut acc = HkAccumulator::new(sp.p(), sp.m());
        acc.accumulate(&c);
        let d = acc.hk_diagonal();
        let mean: f64 = d.iter().sum::<f64>() / d.len() as f64;
        assert!((mean - 1.0).abs() < 1e-12);
    }

    #[test]
    fn theorem7_bound_dominates_empirical() {
        let p = 64;
        let gamma = 0.3;
        let n = 2_000;
        let mut worst = 0.0f64;
        for seed in 0..25 {
            let (sp, c) = chunk(p, gamma, n, 100 + seed);
            let mut acc = HkAccumulator::new(sp.p(), sp.m());
            acc.accumulate(&c);
            worst = worst.max(acc.deviation_norm());
        }
        let m = (gamma * p as f64).round() as usize;
        let t = HkAccumulator::t_for_delta(p, m, n, 1e-3);
        assert!(worst <= t, "worst {worst} bound {t}");
        assert!(t < 10.0 * worst, "bound loose: {t} vs {worst}");
    }

    #[test]
    fn merge_laws() {
        // each item is one cluster-shard's worth of counts, accumulated
        // through accumulate_subset (members partition the chunk); the
        // generic checker covers what the old ad-hoc split test did —
        // subset folds compose back to the full accumulation — plus
        // identity/order/partition invariance. u64 counts: exact eq.
        let (sp, c) = chunk(16, 0.5, 100, 9);
        let items: Vec<HkAccumulator> = (0..5)
            .map(|w| {
                let members: Vec<usize> = (0..100).filter(|i| i % 5 == w).collect();
                let mut acc = HkAccumulator::new(sp.p(), sp.m());
                acc.accumulate_subset(&c, &members);
                acc
            })
            .collect();
        crate::testing::prop::assert_mergeable(
            "hk_merge",
            &items,
            || HkAccumulator::new(sp.p(), sp.m()),
            |a, b| a.merge(b).unwrap(),
            |a, b| a.counts_raw() == b.counts_raw() && a.n() == b.n(),
        );
        // and the fold reproduces the whole-chunk accumulation exactly
        let mut whole = HkAccumulator::new(sp.p(), sp.m());
        whole.accumulate(&c);
        let mut folded = HkAccumulator::new(sp.p(), sp.m());
        for it in &items {
            folded.merge(it).unwrap();
        }
        assert_eq!(whole.counts_raw(), folded.counts_raw());
        assert_eq!(whole.n(), folded.n());
    }

    #[test]
    fn merge_shape_mismatch_is_typed() {
        let mut a = HkAccumulator::new(16, 8);
        let b = HkAccumulator::new(16, 4);
        match a.merge(&b) {
            Err(crate::error::Error::Invalid(_)) => {}
            other => panic!("expected Error::Invalid, got {other:?}"),
        }
    }

    #[test]
    fn delta_roundtrip() {
        let t = HkAccumulator::t_for_delta(100, 30, 5000, 1e-3);
        let back = HkAccumulator::delta_for_t(100, 30, 5000, t);
        assert!((back - 1e-3).abs() / 1e-3 < 1e-6);
    }
}
