//! Theorem 6: the unbiased covariance estimator from sparsified data.
//!
//! Streaming accumulation of `Σ R_i R_iᵀ x_i x_iᵀ R_i R_iᵀ` (each term is
//! an m×m outer-product scatter), the Eq. (21) diagonal unbiasing, and the
//! Eq. (24)–(26) spectral-norm concentration bound.

use crate::estimators::bounds::bernstein_invert;
use crate::linalg::Mat;
use crate::parallel;
use crate::sparse::SparseChunk;

/// Streaming unbiased covariance estimator (Theorem 6), with a second
/// calibration for weighted with-replacement sampling schemes
/// ([`new_weighted`](Self::new_weighted)).
#[derive(Clone, Debug)]
pub struct CovarianceEstimator {
    p: usize,
    m: usize,
    /// Accumulated `Σ w_i w_iᵀ` (dense p×p; the estimator is *for* the
    /// unstructured-covariance regime, so dense accumulation is inherent).
    acc: Mat,
    n: usize,
    /// Fork/join width for [`accumulate`](Self::accumulate). `1` runs the
    /// serial scatter; any value yields a bitwise-identical accumulator
    /// (workers own disjoint column ranges of `acc` and visit samples in
    /// the serial order).
    workers: usize,
    /// Cached weighted column split for the parallel scatter — depends
    /// only on `p` and `workers`, so it is computed once per
    /// [`set_workers`](Self::set_workers) instead of per chunk.
    ranges_cache: Option<Vec<std::ops::Range<usize>>>,
    /// Weighted-scheme calibration: estimate as
    /// `m/((m−1)·n) · (G − diag(slot_diag))` instead of the Eq. 19/21
    /// uniform rescale + diagonal shrink.
    weighted: bool,
    /// Per-coordinate sum of squared *slot* values (`Σ u²` over every
    /// kept slot) — the weighted schemes' diagonal correction. Only
    /// accumulated in weighted mode (for distinct-index chunks it would
    /// equal `diag(acc)`).
    slot_diag: Vec<f64>,
}

impl CovarianceEstimator {
    /// Fresh estimator for chunks of shape `(p, m)` produced by a
    /// **uniform** (without-replacement, unweighted) sampling scheme —
    /// the paper's Theorem 6 calibration.
    pub fn new(p: usize, m: usize) -> Self {
        assert!(m >= 2, "covariance estimator needs m >= 2 (Eq. 19 rescale)");
        CovarianceEstimator {
            p,
            m,
            acc: Mat::zeros(p, p),
            n: 0,
            workers: 1,
            ranges_cache: None,
            weighted: false,
            slot_diag: Vec::new(),
        }
    }

    /// Fresh estimator for chunks from a **weighted with-replacement**
    /// scheme (`sampling::Scheme::Hybrid`): slots store
    /// inverse-probability-scaled draws, duplicates allowed. The estimate
    /// is the exactly unbiased cross-slot form
    /// `m/((m−1)·n) · (G − diag(S))` with `S` the per-slot squares —
    /// see `sampling::scheme` for the derivation.
    pub fn new_weighted(p: usize, m: usize) -> Self {
        assert!(m >= 2, "weighted covariance estimator needs m >= 2 (cross-slot rescale)");
        CovarianceEstimator {
            p,
            m,
            acc: Mat::zeros(p, p),
            n: 0,
            workers: 1,
            ranges_cache: None,
            weighted: true,
            slot_diag: vec![0.0; p],
        }
    }

    /// Whether this estimator uses the weighted-scheme calibration.
    pub fn is_weighted(&self) -> bool {
        self.weighted
    }

    /// Builder-style worker-count override for the scatter accumulation.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.set_workers(workers);
        self
    }

    /// Set the fork/join width used by subsequent
    /// [`accumulate`](Self::accumulate) calls.
    pub fn set_workers(&mut self, workers: usize) {
        self.workers = workers.max(1);
        self.ranges_cache = None;
    }

    /// Fold one sparsified chunk: scatter each column's m×m outer product.
    ///
    /// Perf: only the lower triangle is accumulated (column indices are
    /// sorted, so `b >= a` ⇒ `j_b >= j_a`) and mirrored at estimate time —
    /// half the scatter traffic of the naive m² loop (§Perf log). With
    /// `workers > 1` the scatter is partitioned over *output* columns
    /// (weighted by the triangle height `p − j` so the load balances);
    /// each cell still receives its contributions in sample order, so the
    /// accumulator is bitwise independent of the worker count.
    pub fn accumulate(&mut self, chunk: &SparseChunk) {
        assert_eq!(chunk.p(), self.p);
        assert_eq!(chunk.m(), self.m);
        if self.weighted {
            // per-slot squares for the cross-slot diagonal correction;
            // serial in sample order, so the correction (like the
            // scatter) is independent of chunk boundaries
            for i in 0..chunk.n() {
                for (&j, &v) in chunk.col_indices(i).iter().zip(chunk.col_values(i)) {
                    self.slot_diag[j as usize] += v * v;
                }
            }
        }
        if self.workers > 1 {
            self.accumulate_scatter_par(chunk);
        } else {
            for i in 0..chunk.n() {
                let idx = chunk.col_indices(i);
                let val = chunk.col_values(i);
                for (a, &ja) in idx.iter().enumerate() {
                    let va = val[a];
                    if va == 0.0 {
                        continue;
                    }
                    // sorted indices: writes walk down column `ja`
                    // contiguously
                    for (b, &jb) in idx.iter().enumerate().skip(a) {
                        self.acc.add_at(jb as usize, ja as usize, val[b] * va);
                    }
                }
            }
        }
        self.n += chunk.n();
    }

    /// Column-partitioned parallel scatter: worker `t` owns columns
    /// `ranges[t]` of `acc` (a contiguous panel of the column-major
    /// buffer) and, per sample, binary-searches the sorted index list for
    /// the positions that scatter into its panel. The first (range,
    /// panel) runs inline on the caller — the `parallel::run_ranges` /
    /// `NativeAssigner::assign_into` discipline — so all `workers` cores
    /// do scatter work instead of one sitting in `join`.
    fn accumulate_scatter_par(&mut self, chunk: &SparseChunk) {
        let p = self.p;
        if self.ranges_cache.is_none() {
            // lower-triangle column j receives p − j output rows; balance
            // on that weight instead of column count
            self.ranges_cache = Some(parallel::split_ranges_by_weight(
                p,
                self.workers,
                |j| (p - j) as f64,
            ));
        }
        // borrow the cached split in place (disjoint from the `acc`
        // borrow below — no per-chunk clone)
        let ranges = self.ranges_cache.as_deref().expect("just populated");
        let panels = parallel::split_col_panels(self.acc.as_mut_slice(), p, ranges);
        let jobs: Vec<_> = ranges.iter().cloned().zip(panels).collect();
        let work = |r: std::ops::Range<usize>, panel: &mut [f64]| {
            let (lo, hi) = (r.start as u32, r.end as u32);
            for i in 0..chunk.n() {
                let idx = chunk.col_indices(i);
                let val = chunk.col_values(i);
                let a_lo = idx.partition_point(|&j| j < lo);
                let a_hi = a_lo + idx[a_lo..].partition_point(|&j| j < hi);
                for a in a_lo..a_hi {
                    let ja = idx[a] as usize;
                    let va = val[a];
                    if va == 0.0 {
                        continue;
                    }
                    let col = &mut panel[(ja - r.start) * p..(ja - r.start + 1) * p];
                    for (b, &jb) in idx.iter().enumerate().skip(a) {
                        col[jb as usize] += val[b] * va;
                    }
                }
            }
        };
        parallel::run_panel_jobs(jobs, work);
    }

    /// Materialize the symmetric accumulator (mirror lower → upper).
    fn acc_full(&self) -> Mat {
        let mut full = self.acc.clone();
        for j in 0..self.p {
            for i in (j + 1)..self.p {
                let v = full.get(i, j);
                full.set(j, i, v);
            }
        }
        full
    }

    /// Accumulate a precomputed chunk Gram `W Wᵀ` (from the AOT
    /// `cov_update` executable) for `n_cols` samples. Only the lower
    /// triangle is folded (the internal accumulator is triangular).
    /// Uniform calibration only — a Gram carries no per-slot structure,
    /// so the weighted diagonal correction cannot be recovered from it.
    pub fn accumulate_gram(&mut self, gram: &Mat, n_cols: usize) {
        assert!(!self.weighted, "accumulate_gram applies to uniform-scheme estimators only");
        assert_eq!(gram.rows(), self.p);
        assert_eq!(gram.cols(), self.p);
        for j in 0..self.p {
            for i in j..self.p {
                self.acc.add_at(i, j, gram.get(i, j));
            }
        }
        self.n += n_cols;
    }

    /// Samples seen so far.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The biased rescaled estimator `Ĉ_emp` (Eq. 19). Uniform
    /// calibration only (the weighted estimator has no "biased"
    /// intermediate — its single form is already unbiased).
    pub fn estimate_biased(&self) -> Mat {
        assert!(!self.weighted, "estimate_biased applies to uniform-scheme estimators only");
        assert!(self.n > 0);
        let (p, m) = (self.p as f64, self.m as f64);
        let scale = p * (p - 1.0) / (m * (m - 1.0)) / self.n as f64;
        self.acc_full().scaled(scale)
    }

    /// The unbiased estimator: the Eq. 21 form
    /// `Ĉ_n = Ĉ_emp − (p−m)/(p−1) · diag(Ĉ_emp)` under the uniform
    /// calibration, or the cross-slot form
    /// `m/((m−1)·n) · (G − diag(S))` under the weighted one (exactly
    /// unbiased for the respective scheme; see `sampling::scheme`).
    pub fn estimate(&self) -> Mat {
        if self.weighted {
            assert!(self.n > 0);
            let m = self.m as f64;
            let scale = m / (m - 1.0) / self.n as f64;
            let mut c = self.acc_full();
            // The triangular scatter counts each unordered same-index
            // slot pair once, so acc_jj = (v_j² + S_jj)/2; the ordered
            // cross-slot diagonal Σ_{a≠b} u_a u_b = v_j² − S_jj is
            // therefore 2·(acc_jj − S_jj). Off-diagonals already hold
            // v_j v_k exactly.
            for i in 0..self.p {
                let d = 2.0 * (c.get(i, i) - self.slot_diag[i]);
                c.set(i, i, d);
            }
            return c.scaled(scale);
        }
        let (p, m) = (self.p as f64, self.m as f64);
        let mut c = self.estimate_biased();
        let shrink = (p - m) / (p - 1.0);
        for i in 0..self.p {
            let d = c.get(i, i);
            c.set(i, i, d - shrink * d);
        }
        c
    }

    /// Merge a partner accumulator (distributed reduction).
    pub fn merge(&mut self, other: &CovarianceEstimator) {
        assert_eq!(self.p, other.p);
        assert_eq!(self.m, other.m);
        assert_eq!(self.weighted, other.weighted, "cannot merge mixed calibrations");
        self.acc.axpy(1.0, &other.acc);
        for (a, b) in self.slot_diag.iter_mut().zip(&other.slot_diag) {
            *a += b;
        }
        self.n += other.n;
    }

    /// `(p, m)` the estimator was built for.
    pub(crate) fn shape(&self) -> (usize, usize) {
        (self.p, self.m)
    }

    /// The raw accumulated scatter (lower triangle populated) — the
    /// serializable state, together with [`slot_diag_raw`](Self::slot_diag_raw).
    pub(crate) fn acc_raw(&self) -> &Mat {
        &self.acc
    }

    /// Raw per-coordinate slot-square sums (empty in uniform mode).
    pub(crate) fn slot_diag_raw(&self) -> &[f64] {
        &self.slot_diag
    }

    /// Rebuild from serialized state (the `distributed` codec). Worker
    /// count is runtime configuration, not state — it resets to 1.
    pub(crate) fn from_raw(
        p: usize,
        m: usize,
        weighted: bool,
        acc: Mat,
        slot_diag: Vec<f64>,
        n: usize,
    ) -> Self {
        assert_eq!((acc.rows(), acc.cols()), (p, p), "covariance state shape mismatch");
        assert_eq!(slot_diag.len(), if weighted { p } else { 0 }, "slot_diag length mismatch");
        CovarianceEstimator { p, m, acc, n, workers: 1, ranges_cache: None, weighted, slot_diag }
    }
}

/// Inputs to the Theorem 6 bound (Eqs. 24–26). All norms refer to the
/// (preconditioned) matrix actually sampled.
#[derive(Clone, Copy, Debug)]
pub struct CovBoundInputs {
    /// Ambient dimension.
    pub p: usize,
    /// Kept entries per sample.
    pub m: usize,
    /// Sample count.
    pub n: usize,
    /// ρ: `max_i ‖w_i‖²/‖x_i‖²` bound (1 always valid; with ROS use
    /// [`rho_preconditioned`](super::rho_preconditioned)).
    pub rho: f64,
    /// `‖X‖max-col²`.
    pub max_col_norm2: f64,
    /// `‖X‖max²`.
    pub max_abs2: f64,
    /// `‖X‖F²`.
    pub frob2: f64,
    /// `‖C_emp‖₂`.
    pub cov_norm: f64,
    /// `‖diag(C_emp)‖₂`.
    pub cov_diag_norm: f64,
    /// `max_j Σ_i X_{j,i}⁴`.
    pub max_row_pow4: f64,
}

impl CovBoundInputs {
    /// The uniform summand bound `L` — Eq. (25).
    pub fn l(&self) -> f64 {
        let (p, m, n) = (self.p as f64, self.m as f64, self.n as f64);
        (1.0 / n)
            * ((p * (p - 1.0) / (m * (m - 1.0)) * self.rho + 1.0) * self.max_col_norm2
                + p * (p - m) / (m * (m - 1.0)) * self.max_abs2)
    }

    /// The variance bound `σ²` — Eq. (26).
    pub fn sigma2(&self) -> f64 {
        let (p, m, n) = (self.p as f64, self.m as f64, self.n as f64);
        let t1 = (p * (p - 1.0) / (m * (m - 1.0)) * self.rho - 1.0)
            * self.max_col_norm2
            * self.cov_norm;
        let t2 = p * (p - 1.0) * (p - m) / (m * (m - 1.0).powi(2))
            * self.rho
            * self.max_col_norm2
            * self.cov_diag_norm;
        let t3 = 2.0 * p * (p - 1.0) * (p - m) / (m * (m - 1.0).powi(2))
            * self.max_abs2
            * (self.frob2 / n);
        let t4 = p * (p - m).powi(2) / (m * (m - 1.0).powi(2)) * (self.max_row_pow4 / n);
        (t1 + t2 + t3 + t4) / n
    }

    /// Spectral-norm error bound `t` at failure probability δ₂ — Eq. (24).
    pub fn t_for_delta(&self, delta2: f64) -> f64 {
        bernstein_invert(self.sigma2(), self.l(), self.p as f64, delta2)
    }

    /// Failure probability δ₂ at error level `t`.
    pub fn delta_for_t(&self, t: f64) -> f64 {
        self.p as f64 * (-(t * t) / 2.0 / (self.sigma2() + self.l() * t / 3.0)).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::spectral_norm_sym;
    use crate::sampling::{Sparsifier, SparsifyConfig};
    use crate::transform::TransformKind;

    /// The k=3 spiked workload all these tests were calibrated on
    /// (λ = 3, 2, 1), from the shared fixture pool — identical bytes to
    /// the local builder this replaced.
    fn spiked_data(p: usize, n: usize, seed: u64) -> Mat {
        crate::testing::fixtures::spiked_data(p, n, &[3.0, 2.0, 1.0], seed)
    }

    #[test]
    fn unbiased_diagonal_correction() {
        // With heavy averaging, Ĉ_n ≈ C_emp including the diagonal —
        // verifying the Eq. 21 unbiasing empirically.
        let (p, n) = (16usize, 60_000usize);
        let x = spiked_data(p, n, 3);
        let cfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 7 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let y = sp.precondition_dense(&x);
        let cemp = y.syrk().scaled(1.0 / n as f64);
        let chunk = sp.compress_chunk(&x, 0).unwrap();
        let mut est = CovarianceEstimator::new(sp.p(), sp.m());
        est.accumulate(&chunk);
        let chat = est.estimate();
        let err = spectral_norm_sym(&chat.sub(&cemp), 1e-9, 2000);
        let scale = spectral_norm_sym(&cemp, 1e-9, 2000);
        assert!(err / scale < 0.15, "relative err {}", err / scale);
        // biased estimator must differ on the diagonal by the known factor
        let biased = est.estimate_biased();
        let d_biased: f64 = biased.diagonal().iter().sum();
        let d_unbiased: f64 = chat.diagonal().iter().sum();
        assert!(d_biased > d_unbiased, "bias correction must shrink diagonal");
    }

    #[test]
    fn merge_and_gram_paths_agree() {
        let (p, n) = (12usize, 64usize);
        let x = spiked_data(p, n, 5);
        let cfg = SparsifyConfig { gamma: 0.4, transform: TransformKind::Hadamard, seed: 9 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let chunk = sp.compress_chunk(&x, 0).unwrap();

        let mut scatter = CovarianceEstimator::new(sp.p(), sp.m());
        scatter.accumulate(&chunk);

        let w = chunk.to_dense();
        let mut gram = CovarianceEstimator::new(sp.p(), sp.m());
        gram.accumulate_gram(&w.syrk(), n);

        let d = scatter.estimate().sub(&gram.estimate());
        assert!(d.max_abs() < 1e-9, "scatter vs gram {}", d.max_abs());

        // split + merge == whole
        let mut a = CovarianceEstimator::new(sp.p(), sp.m());
        let mut b = CovarianceEstimator::new(sp.p(), sp.m());
        a.accumulate(&sp.compress_chunk(&x.col_range(0, 40), 0).unwrap());
        b.accumulate(&sp.compress_chunk(&x.col_range(40, 64), 40).unwrap());
        a.merge(&b);
        let d2 = a.estimate().sub(&scatter.estimate());
        assert!(d2.max_abs() < 1e-9);
    }

    #[test]
    fn workers_do_not_change_the_accumulator() {
        // column-partitioned scatter: every worker count must reproduce
        // the serial accumulator bit for bit, including across several
        // accumulate() calls into the same estimator. `1` is in the list
        // as the inline-first regression guard: running the first (range,
        // panel) on the caller must not perturb any path.
        let (p, n) = (48usize, 200usize);
        let x = spiked_data(p, n, 21);
        let cfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 13 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let c0 = sp.compress_chunk(&x.col_range(0, 90), 0).unwrap();
        let c1 = sp.compress_chunk(&x.col_range(90, 200), 90).unwrap();

        let mut serial = CovarianceEstimator::new(sp.p(), sp.m());
        serial.accumulate(&c0);
        serial.accumulate(&c1);
        let e_serial = serial.estimate();

        for w in [1usize, 2, 4, 7] {
            let mut par = CovarianceEstimator::new(sp.p(), sp.m()).with_workers(w);
            par.accumulate(&c0);
            par.accumulate(&c1);
            assert_eq!(par.n(), serial.n());
            let e_par = par.estimate();
            for (a, b) in e_serial.as_slice().iter().zip(e_par.as_slice()) {
                assert_eq!(a.to_bits(), b.to_bits(), "workers={w}");
            }
        }
    }

    #[test]
    fn inline_first_scatter_regression_workers_124() {
        // regression for the inline-first change: the first (range,
        // panel) now runs on the calling thread and the cached split is
        // borrowed instead of cloned per chunk — the scatter bits must be
        // unchanged for workers ∈ {1, 2, 4}, on raw random chunks too
        let chunk_a = crate::testing::fixtures::sparse_chunk(40, 7, 150, 0, 91);
        let chunk_b = crate::testing::fixtures::sparse_chunk(40, 7, 60, 150, 92);
        let mut serial = CovarianceEstimator::new(40, 7);
        serial.accumulate(&chunk_a);
        serial.accumulate(&chunk_b);
        let e_serial = serial.estimate();
        for w in [1usize, 2, 4] {
            let mut par = CovarianceEstimator::new(40, 7).with_workers(w);
            par.accumulate(&chunk_a);
            par.accumulate(&chunk_b);
            let e_par = par.estimate();
            for (a, b) in e_serial.as_slice().iter().zip(e_par.as_slice()) {
                assert_eq!(a.to_bits(), b.to_bits(), "workers={w}");
            }
        }
    }

    #[test]
    fn hybrid_weighted_estimator_is_unbiased_monte_carlo() {
        // The scheme-layer contract: under Scheme::Hybrid
        // (inverse-probability-weighted with-replacement slots) the
        // cross-slot estimate m/((m−1)n)·(G − diag(S)) is *exactly*
        // unbiased for C_emp = X Xᵀ/n of the raw data — every entry,
        // diagonal included. Verified by Monte Carlo over independent
        // scheme seeds with a self-calibrated tolerance (6 standard
        // errors per entry), so no hand-tuned constants.
        use crate::sampling::Scheme;
        let (p, n, trials) = (16usize, 4usize, 8000usize);
        let mut rng = Pcg64::seed(51);
        let x = Mat::from_fn(p, n, |_, _| rng.normal());
        let truth = x.syrk().scaled(1.0 / n as f64);
        let mut sum = Mat::zeros(p, p);
        let mut sumsq = Mat::zeros(p, p);
        let mut m_kept = 0usize;
        for t in 0..trials {
            let cfg = SparsifyConfig {
                gamma: 0.375, // m = 6 of p = 16
                transform: TransformKind::Hadamard,
                seed: 90_000 + t as u64,
            };
            let sp = Sparsifier::with_scheme(p, cfg, Scheme::Hybrid).unwrap();
            m_kept = sp.m();
            let chunk = sp.compress_chunk(&x, 0).unwrap();
            let mut est = CovarianceEstimator::new_weighted(sp.p(), sp.m());
            est.accumulate(&chunk);
            let c = est.estimate();
            for (i, &v) in c.as_slice().iter().enumerate() {
                sum.as_mut_slice()[i] += v;
                sumsq.as_mut_slice()[i] += v * v;
            }
        }
        assert_eq!(m_kept, 6);
        let tf = trials as f64;
        let mut max_sigmas = 0.0f64;
        for i in 0..p * p {
            let mean = sum.as_slice()[i] / tf;
            let var = (sumsq.as_slice()[i] / tf - mean * mean).max(0.0);
            let se = (var / tf).sqrt();
            let err = (mean - truth.as_slice()[i]).abs();
            assert!(
                err <= 6.0 * se + 1e-9,
                "entry {i}: |bias| {err} exceeds 6·SE {se} (mean {mean} vs truth {})",
                truth.as_slice()[i]
            );
            if se > 0.0 {
                max_sigmas = max_sigmas.max(err / se);
            }
        }
        // sanity: the estimator is genuinely random (the tolerance is not
        // vacuously tight or vacuously loose)
        assert!(max_sigmas > 0.0);
    }

    #[test]
    fn weighted_accumulation_is_worker_and_chunking_invariant() {
        use crate::sampling::Scheme;
        let (p, n) = (32usize, 400usize);
        let mut rng = Pcg64::seed(61);
        let x = Mat::from_fn(p, n, |_, _| rng.normal());
        let cfg = SparsifyConfig { gamma: 0.25, transform: TransformKind::Hadamard, seed: 17 };
        let sp = Sparsifier::with_scheme(p, cfg, Scheme::Hybrid).unwrap();
        let whole = sp.compress_chunk(&x, 0).unwrap();
        let mut base = CovarianceEstimator::new_weighted(sp.p(), sp.m());
        base.accumulate(&whole);
        let e_base = base.estimate();
        for (workers, splits) in [(1usize, vec![150usize]), (2, vec![150]), (4, vec![37, 251])] {
            let mut est = CovarianceEstimator::new_weighted(sp.p(), sp.m()).with_workers(workers);
            let mut a = 0usize;
            for &b in splits.iter().chain(std::iter::once(&n)) {
                est.accumulate(&sp.compress_chunk(&x.col_range(a, b), a).unwrap());
                a = b;
            }
            assert_eq!(est.n(), n);
            let e = est.estimate();
            for (u, v) in e.as_slice().iter().zip(e_base.as_slice()) {
                assert_eq!(u.to_bits(), v.to_bits(), "workers={workers}");
            }
        }
        // split + merge agrees with the single accumulator (up to f64
        // re-association across the merge boundary, as in the uniform
        // merge test)
        let mut left = CovarianceEstimator::new_weighted(sp.p(), sp.m());
        let mut right = CovarianceEstimator::new_weighted(sp.p(), sp.m());
        left.accumulate(&sp.compress_chunk(&x.col_range(0, 220), 0).unwrap());
        right.accumulate(&sp.compress_chunk(&x.col_range(220, n), 220).unwrap());
        left.merge(&right);
        let d = left.estimate().sub(&e_base);
        assert!(d.max_abs() < 1e-9, "merge drift {}", d.max_abs());
    }

    #[test]
    fn bound_roundtrip_and_dominance() {
        let (p, n) = (32usize, 4_000usize);
        let x = spiked_data(p, n, 11);
        let cfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 1 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let y = sp.precondition_dense(&x);
        let cemp = y.syrk().scaled(1.0 / n as f64);
        let chunk = sp.compress_chunk(&x, 0).unwrap();
        let mut est = CovarianceEstimator::new(sp.p(), sp.m());
        est.accumulate(&chunk);
        let err = spectral_norm_sym(&est.estimate().sub(&cemp), 1e-9, 2000);

        let mut stats = crate::estimators::DataStats::new(sp.p());
        stats.accumulate(&y);
        let inputs = CovBoundInputs {
            p: sp.p(),
            m: sp.m(),
            n,
            rho: crate::estimators::rho_preconditioned(sp.m(), sp.p(), n, 1.0, 0.01),
            max_col_norm2: stats.max_col_norm().powi(2),
            max_abs2: stats.max_abs().powi(2),
            frob2: stats.frob2(),
            cov_norm: spectral_norm_sym(&cemp, 1e-9, 2000),
            cov_diag_norm: cemp.diagonal().iter().fold(0.0f64, |a, &b| a.max(b.abs())),
            max_row_pow4: stats.max_row_pow4(),
        };
        let t = inputs.t_for_delta(0.01);
        assert!(err <= t, "bound must dominate: err {err} t {t}");
        // tightness within the paper's "order of magnitude"
        assert!(t < 100.0 * err, "bound wildly loose: err {err} t {t}");
        // tail inversion roundtrip
        let back = inputs.delta_for_t(t);
        assert!((back - 0.01).abs() / 0.01 < 1e-6);
    }
}
