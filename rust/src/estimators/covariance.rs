//! Theorem 6: the unbiased covariance estimator from sparsified data.
//!
//! Streaming accumulation of `Σ R_i R_iᵀ x_i x_iᵀ R_i R_iᵀ` (each term is
//! an m×m outer-product scatter), the Eq. (21) diagonal unbiasing, and the
//! Eq. (24)–(26) spectral-norm concentration bound.

use crate::estimators::bounds::bernstein_invert;
use crate::linalg::Mat;
use crate::parallel;
use crate::sparse::SparseChunk;

/// Streaming unbiased covariance estimator (Theorem 6).
#[derive(Clone, Debug)]
pub struct CovarianceEstimator {
    p: usize,
    m: usize,
    /// Accumulated `Σ w_i w_iᵀ` (dense p×p; the estimator is *for* the
    /// unstructured-covariance regime, so dense accumulation is inherent).
    acc: Mat,
    n: usize,
    /// Fork/join width for [`accumulate`](Self::accumulate). `1` runs the
    /// serial scatter; any value yields a bitwise-identical accumulator
    /// (workers own disjoint column ranges of `acc` and visit samples in
    /// the serial order).
    workers: usize,
    /// Cached weighted column split for the parallel scatter — depends
    /// only on `p` and `workers`, so it is computed once per
    /// [`set_workers`](Self::set_workers) instead of per chunk.
    ranges_cache: Option<Vec<std::ops::Range<usize>>>,
}

impl CovarianceEstimator {
    /// Fresh estimator for chunks of shape `(p, m)`.
    pub fn new(p: usize, m: usize) -> Self {
        assert!(m >= 2, "covariance estimator needs m >= 2 (Eq. 19 rescale)");
        CovarianceEstimator { p, m, acc: Mat::zeros(p, p), n: 0, workers: 1, ranges_cache: None }
    }

    /// Builder-style worker-count override for the scatter accumulation.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.set_workers(workers);
        self
    }

    /// Set the fork/join width used by subsequent
    /// [`accumulate`](Self::accumulate) calls.
    pub fn set_workers(&mut self, workers: usize) {
        self.workers = workers.max(1);
        self.ranges_cache = None;
    }

    /// Fold one sparsified chunk: scatter each column's m×m outer product.
    ///
    /// Perf: only the lower triangle is accumulated (column indices are
    /// sorted, so `b >= a` ⇒ `j_b >= j_a`) and mirrored at estimate time —
    /// half the scatter traffic of the naive m² loop (§Perf log). With
    /// `workers > 1` the scatter is partitioned over *output* columns
    /// (weighted by the triangle height `p − j` so the load balances);
    /// each cell still receives its contributions in sample order, so the
    /// accumulator is bitwise independent of the worker count.
    pub fn accumulate(&mut self, chunk: &SparseChunk) {
        assert_eq!(chunk.p(), self.p);
        assert_eq!(chunk.m(), self.m);
        if self.workers > 1 {
            self.accumulate_scatter_par(chunk);
        } else {
            for i in 0..chunk.n() {
                let idx = chunk.col_indices(i);
                let val = chunk.col_values(i);
                for (a, &ja) in idx.iter().enumerate() {
                    let va = val[a];
                    if va == 0.0 {
                        continue;
                    }
                    // sorted indices: writes walk down column `ja`
                    // contiguously
                    for (b, &jb) in idx.iter().enumerate().skip(a) {
                        self.acc.add_at(jb as usize, ja as usize, val[b] * va);
                    }
                }
            }
        }
        self.n += chunk.n();
    }

    /// Column-partitioned parallel scatter: worker `t` owns columns
    /// `ranges[t]` of `acc` (a contiguous panel of the column-major
    /// buffer) and, per sample, binary-searches the sorted index list for
    /// the positions that scatter into its panel. The first (range,
    /// panel) runs inline on the caller — the `parallel::run_ranges` /
    /// `NativeAssigner::assign_into` discipline — so all `workers` cores
    /// do scatter work instead of one sitting in `join`.
    fn accumulate_scatter_par(&mut self, chunk: &SparseChunk) {
        let p = self.p;
        if self.ranges_cache.is_none() {
            // lower-triangle column j receives p − j output rows; balance
            // on that weight instead of column count
            self.ranges_cache = Some(parallel::split_ranges_by_weight(
                p,
                self.workers,
                |j| (p - j) as f64,
            ));
        }
        // borrow the cached split in place (disjoint from the `acc`
        // borrow below — no per-chunk clone)
        let ranges = self.ranges_cache.as_deref().expect("just populated");
        let panels = parallel::split_col_panels(self.acc.as_mut_slice(), p, ranges);
        let jobs: Vec<_> = ranges.iter().cloned().zip(panels).collect();
        let work = |r: std::ops::Range<usize>, panel: &mut [f64]| {
            let (lo, hi) = (r.start as u32, r.end as u32);
            for i in 0..chunk.n() {
                let idx = chunk.col_indices(i);
                let val = chunk.col_values(i);
                let a_lo = idx.partition_point(|&j| j < lo);
                let a_hi = a_lo + idx[a_lo..].partition_point(|&j| j < hi);
                for a in a_lo..a_hi {
                    let ja = idx[a] as usize;
                    let va = val[a];
                    if va == 0.0 {
                        continue;
                    }
                    let col = &mut panel[(ja - r.start) * p..(ja - r.start + 1) * p];
                    for (b, &jb) in idx.iter().enumerate().skip(a) {
                        col[jb as usize] += val[b] * va;
                    }
                }
            }
        };
        parallel::run_panel_jobs(jobs, work);
    }

    /// Materialize the symmetric accumulator (mirror lower → upper).
    fn acc_full(&self) -> Mat {
        let mut full = self.acc.clone();
        for j in 0..self.p {
            for i in (j + 1)..self.p {
                let v = full.get(i, j);
                full.set(j, i, v);
            }
        }
        full
    }

    /// Accumulate a precomputed chunk Gram `W Wᵀ` (from the AOT
    /// `cov_update` executable) for `n_cols` samples. Only the lower
    /// triangle is folded (the internal accumulator is triangular).
    pub fn accumulate_gram(&mut self, gram: &Mat, n_cols: usize) {
        assert_eq!(gram.rows(), self.p);
        assert_eq!(gram.cols(), self.p);
        for j in 0..self.p {
            for i in j..self.p {
                self.acc.add_at(i, j, gram.get(i, j));
            }
        }
        self.n += n_cols;
    }

    /// Samples seen so far.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The biased rescaled estimator `Ĉ_emp` (Eq. 19).
    pub fn estimate_biased(&self) -> Mat {
        assert!(self.n > 0);
        let (p, m) = (self.p as f64, self.m as f64);
        let scale = p * (p - 1.0) / (m * (m - 1.0)) / self.n as f64;
        self.acc_full().scaled(scale)
    }

    /// The unbiased estimator `Ĉ_n` (Eq. 21):
    /// `Ĉ_n = Ĉ_emp − (p−m)/(p−1) · diag(Ĉ_emp)`.
    pub fn estimate(&self) -> Mat {
        let (p, m) = (self.p as f64, self.m as f64);
        let mut c = self.estimate_biased();
        let shrink = (p - m) / (p - 1.0);
        for i in 0..self.p {
            let d = c.get(i, i);
            c.set(i, i, d - shrink * d);
        }
        c
    }

    /// Merge a partner accumulator (distributed reduction).
    pub fn merge(&mut self, other: &CovarianceEstimator) {
        assert_eq!(self.p, other.p);
        assert_eq!(self.m, other.m);
        self.acc.axpy(1.0, &other.acc);
        self.n += other.n;
    }
}

/// Inputs to the Theorem 6 bound (Eqs. 24–26). All norms refer to the
/// (preconditioned) matrix actually sampled.
#[derive(Clone, Copy, Debug)]
pub struct CovBoundInputs {
    /// Ambient dimension.
    pub p: usize,
    /// Kept entries per sample.
    pub m: usize,
    /// Sample count.
    pub n: usize,
    /// ρ: `max_i ‖w_i‖²/‖x_i‖²` bound (1 always valid; with ROS use
    /// [`rho_preconditioned`](super::rho_preconditioned)).
    pub rho: f64,
    /// `‖X‖max-col²`.
    pub max_col_norm2: f64,
    /// `‖X‖max²`.
    pub max_abs2: f64,
    /// `‖X‖F²`.
    pub frob2: f64,
    /// `‖C_emp‖₂`.
    pub cov_norm: f64,
    /// `‖diag(C_emp)‖₂`.
    pub cov_diag_norm: f64,
    /// `max_j Σ_i X_{j,i}⁴`.
    pub max_row_pow4: f64,
}

impl CovBoundInputs {
    /// The uniform summand bound `L` — Eq. (25).
    pub fn l(&self) -> f64 {
        let (p, m, n) = (self.p as f64, self.m as f64, self.n as f64);
        (1.0 / n)
            * ((p * (p - 1.0) / (m * (m - 1.0)) * self.rho + 1.0) * self.max_col_norm2
                + p * (p - m) / (m * (m - 1.0)) * self.max_abs2)
    }

    /// The variance bound `σ²` — Eq. (26).
    pub fn sigma2(&self) -> f64 {
        let (p, m, n) = (self.p as f64, self.m as f64, self.n as f64);
        let t1 = (p * (p - 1.0) / (m * (m - 1.0)) * self.rho - 1.0)
            * self.max_col_norm2
            * self.cov_norm;
        let t2 = p * (p - 1.0) * (p - m) / (m * (m - 1.0).powi(2))
            * self.rho
            * self.max_col_norm2
            * self.cov_diag_norm;
        let t3 = 2.0 * p * (p - 1.0) * (p - m) / (m * (m - 1.0).powi(2))
            * self.max_abs2
            * (self.frob2 / n);
        let t4 = p * (p - m).powi(2) / (m * (m - 1.0).powi(2)) * (self.max_row_pow4 / n);
        (t1 + t2 + t3 + t4) / n
    }

    /// Spectral-norm error bound `t` at failure probability δ₂ — Eq. (24).
    pub fn t_for_delta(&self, delta2: f64) -> f64 {
        bernstein_invert(self.sigma2(), self.l(), self.p as f64, delta2)
    }

    /// Failure probability δ₂ at error level `t`.
    pub fn delta_for_t(&self, t: f64) -> f64 {
        self.p as f64 * (-(t * t) / 2.0 / (self.sigma2() + self.l() * t / 3.0)).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::spectral_norm_sym;
    use crate::sampling::{Sparsifier, SparsifyConfig};
    use crate::transform::TransformKind;

    /// The k=3 spiked workload all these tests were calibrated on
    /// (λ = 3, 2, 1), from the shared fixture pool — identical bytes to
    /// the local builder this replaced.
    fn spiked_data(p: usize, n: usize, seed: u64) -> Mat {
        crate::testing::fixtures::spiked_data(p, n, &[3.0, 2.0, 1.0], seed)
    }

    #[test]
    fn unbiased_diagonal_correction() {
        // With heavy averaging, Ĉ_n ≈ C_emp including the diagonal —
        // verifying the Eq. 21 unbiasing empirically.
        let (p, n) = (16usize, 60_000usize);
        let x = spiked_data(p, n, 3);
        let cfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 7 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let y = sp.precondition_dense(&x);
        let cemp = y.syrk().scaled(1.0 / n as f64);
        let chunk = sp.compress_chunk(&x, 0).unwrap();
        let mut est = CovarianceEstimator::new(sp.p(), sp.m());
        est.accumulate(&chunk);
        let chat = est.estimate();
        let err = spectral_norm_sym(&chat.sub(&cemp), 1e-9, 2000);
        let scale = spectral_norm_sym(&cemp, 1e-9, 2000);
        assert!(err / scale < 0.15, "relative err {}", err / scale);
        // biased estimator must differ on the diagonal by the known factor
        let biased = est.estimate_biased();
        let d_biased: f64 = biased.diagonal().iter().sum();
        let d_unbiased: f64 = chat.diagonal().iter().sum();
        assert!(d_biased > d_unbiased, "bias correction must shrink diagonal");
    }

    #[test]
    fn merge_and_gram_paths_agree() {
        let (p, n) = (12usize, 64usize);
        let x = spiked_data(p, n, 5);
        let cfg = SparsifyConfig { gamma: 0.4, transform: TransformKind::Hadamard, seed: 9 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let chunk = sp.compress_chunk(&x, 0).unwrap();

        let mut scatter = CovarianceEstimator::new(sp.p(), sp.m());
        scatter.accumulate(&chunk);

        let w = chunk.to_dense();
        let mut gram = CovarianceEstimator::new(sp.p(), sp.m());
        gram.accumulate_gram(&w.syrk(), n);

        let d = scatter.estimate().sub(&gram.estimate());
        assert!(d.max_abs() < 1e-9, "scatter vs gram {}", d.max_abs());

        // split + merge == whole
        let mut a = CovarianceEstimator::new(sp.p(), sp.m());
        let mut b = CovarianceEstimator::new(sp.p(), sp.m());
        a.accumulate(&sp.compress_chunk(&x.col_range(0, 40), 0).unwrap());
        b.accumulate(&sp.compress_chunk(&x.col_range(40, 64), 40).unwrap());
        a.merge(&b);
        let d2 = a.estimate().sub(&scatter.estimate());
        assert!(d2.max_abs() < 1e-9);
    }

    #[test]
    fn workers_do_not_change_the_accumulator() {
        // column-partitioned scatter: every worker count must reproduce
        // the serial accumulator bit for bit, including across several
        // accumulate() calls into the same estimator. `1` is in the list
        // as the inline-first regression guard: running the first (range,
        // panel) on the caller must not perturb any path.
        let (p, n) = (48usize, 200usize);
        let x = spiked_data(p, n, 21);
        let cfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 13 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let c0 = sp.compress_chunk(&x.col_range(0, 90), 0).unwrap();
        let c1 = sp.compress_chunk(&x.col_range(90, 200), 90).unwrap();

        let mut serial = CovarianceEstimator::new(sp.p(), sp.m());
        serial.accumulate(&c0);
        serial.accumulate(&c1);
        let e_serial = serial.estimate();

        for w in [1usize, 2, 4, 7] {
            let mut par = CovarianceEstimator::new(sp.p(), sp.m()).with_workers(w);
            par.accumulate(&c0);
            par.accumulate(&c1);
            assert_eq!(par.n(), serial.n());
            let e_par = par.estimate();
            for (a, b) in e_serial.as_slice().iter().zip(e_par.as_slice()) {
                assert_eq!(a.to_bits(), b.to_bits(), "workers={w}");
            }
        }
    }

    #[test]
    fn inline_first_scatter_regression_workers_124() {
        // regression for the inline-first change: the first (range,
        // panel) now runs on the calling thread and the cached split is
        // borrowed instead of cloned per chunk — the scatter bits must be
        // unchanged for workers ∈ {1, 2, 4}, on raw random chunks too
        let chunk_a = crate::testing::fixtures::sparse_chunk(40, 7, 150, 0, 91);
        let chunk_b = crate::testing::fixtures::sparse_chunk(40, 7, 60, 150, 92);
        let mut serial = CovarianceEstimator::new(40, 7);
        serial.accumulate(&chunk_a);
        serial.accumulate(&chunk_b);
        let e_serial = serial.estimate();
        for w in [1usize, 2, 4] {
            let mut par = CovarianceEstimator::new(40, 7).with_workers(w);
            par.accumulate(&chunk_a);
            par.accumulate(&chunk_b);
            let e_par = par.estimate();
            for (a, b) in e_serial.as_slice().iter().zip(e_par.as_slice()) {
                assert_eq!(a.to_bits(), b.to_bits(), "workers={w}");
            }
        }
    }

    #[test]
    fn bound_roundtrip_and_dominance() {
        let (p, n) = (32usize, 4_000usize);
        let x = spiked_data(p, n, 11);
        let cfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 1 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let y = sp.precondition_dense(&x);
        let cemp = y.syrk().scaled(1.0 / n as f64);
        let chunk = sp.compress_chunk(&x, 0).unwrap();
        let mut est = CovarianceEstimator::new(sp.p(), sp.m());
        est.accumulate(&chunk);
        let err = spectral_norm_sym(&est.estimate().sub(&cemp), 1e-9, 2000);

        let mut stats = crate::estimators::DataStats::new(sp.p());
        stats.accumulate(&y);
        let inputs = CovBoundInputs {
            p: sp.p(),
            m: sp.m(),
            n,
            rho: crate::estimators::rho_preconditioned(sp.m(), sp.p(), n, 1.0, 0.01),
            max_col_norm2: stats.max_col_norm().powi(2),
            max_abs2: stats.max_abs().powi(2),
            frob2: stats.frob2(),
            cov_norm: spectral_norm_sym(&cemp, 1e-9, 2000),
            cov_diag_norm: cemp.diagonal().iter().fold(0.0f64, |a, &b| a.max(b.abs())),
            max_row_pow4: stats.max_row_pow4(),
        };
        let t = inputs.t_for_delta(0.01);
        assert!(err <= t, "bound must dominate: err {err} t {t}");
        // tightness within the paper's "order of magnitude"
        assert!(t < 100.0 * err, "bound wildly loose: err {err} t {t}");
        // tail inversion roundtrip
        let back = inputs.delta_for_t(t);
        assert!((back - 0.01).abs() / 0.01 < 1e-6);
    }
}
