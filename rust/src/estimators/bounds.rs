//! Shared concentration-bound machinery: Bernstein inversion, the τ and ρ
//! quantities, Corollary 5's sample-size law, and a streaming accumulator
//! for the data-dependent norms the bounds consume.

use crate::linalg::Mat;

/// `τ(m, p) = max(p/m − 1, 1)` — Eq. (9).
///
/// Panics (rather than silently producing a non-finite bound that
/// propagates into reports) when `m = 0`.
pub fn tau(m: usize, p: usize) -> f64 {
    assert!(m > 0, "tau: m must be positive (division by m)");
    (p as f64 / m as f64 - 1.0).max(1.0)
}

/// Invert a (matrix) Bernstein tail `δ = prefactor · exp(−t²/2 / (σ² + L t / 3))`
/// for `t` at a given failure probability: with `lf = ln(prefactor/δ)`,
/// `t = L·lf/3 + sqrt((L·lf/3)² + 2 σ² lf)`.
///
/// Requires `delta > 0` and `prefactor > 0` (asserted). When
/// `delta ≥ prefactor` the tail constraint is vacuous — any `t ≥ 0`
/// satisfies it — so `lf` clamps at 0 and the function returns the
/// degenerate (but correct) bound `t = 0`; callers that treat the return
/// value as a meaningful error radius should keep `delta < prefactor`.
pub fn bernstein_invert(sigma2: f64, l: f64, prefactor: f64, delta: f64) -> f64 {
    assert!(
        delta > 0.0 && prefactor > 0.0,
        "bernstein_invert: delta and prefactor must be positive (got delta={delta}, \
         prefactor={prefactor})"
    );
    let lf = (prefactor / delta).ln().max(0.0);
    let a = l * lf / 3.0;
    a + (a * a + 2.0 * sigma2 * lf).sqrt()
}

/// The paper's per-step K-means center-error guarantee (§V, the Eq. 43
/// deviation behind the Theorem "error in the center estimators at a
/// given step"): the smallest `t` such that the masked center update for
/// a cluster with `n_k` members satisfies `‖H_k − I‖₂ ≤ t` with
/// probability ≥ 1 − δ — i.e. the entry-wise averaging of Eq. (39) is a
/// `(1 ± t)`-perturbation of the plain class mean. Evaluated per Lloyd
/// iteration (per cluster, from the observed cluster sizes) by the
/// K-means fit and surfaced through
/// [`FitReport::center_bound`](crate::coordinator::FitReport).
///
/// With `r = p/m`: `σ² = (r − 1)/n_k`, `L = (r + 1)/n_k`, prefactor `p`
/// (the matrix-Bernstein union over coordinates), inverted by
/// [`bernstein_invert`].
pub fn center_error_bound(p: usize, m: usize, n_k: usize, delta: f64) -> f64 {
    assert!(n_k > 0, "center_error_bound needs a non-empty cluster");
    assert!(m > 0, "center_error_bound: m must be positive (division by m)");
    let r = p as f64 / m as f64;
    let nk = n_k as f64;
    let sigma2 = (r - 1.0) / nk;
    let l = (r + 1.0) / nk;
    bernstein_invert(sigma2, l, p as f64, delta)
}

/// Corollary 3 / Section V: the norm-reduction factor ρ after
/// preconditioning — `ρ = (m/p)(2/η) log(2np/α)` (valid w.p. ≥ 1−α),
/// clipped at the trivial ρ = 1.
pub fn rho_preconditioned(m: usize, p: usize, n: usize, eta: f64, alpha: f64) -> f64 {
    let rho = (m as f64 / p as f64) * (2.0 / eta) * (2.0 * (n * p) as f64 / alpha).ln();
    rho.min(1.0)
}

/// Corollary 5, Eq. (18): the smallest `m` guaranteeing ℓ∞ mean error ≤ t
/// with failure probability δ₁ ≤ 1e−3 for preconditioned data.
/// Returns the (real-valued) lower bound; callers take `ceil` and clamp ≥ 2.
pub fn corollary5_min_m(p: usize, n: usize, t: f64, eta: f64) -> f64 {
    let pf = p as f64;
    let nf = n as f64;
    (1.0 / nf)
        * (4.0 / eta)
        * (200.0 * nf * pf).ln()
        * (2000.0 * pf).ln()
        * (t.powi(-2) + pf.sqrt() / (3.0 * t))
}

/// Streaming accumulator for the data-dependent norms in Theorems 4/6:
/// `‖X‖max`, `‖X‖max-col`, `‖X‖max-row`, `‖X‖F²`, and the max row sum of
/// 4th powers (Eq. 26's last term). Feed dense chunks as they stream by.
#[derive(Clone, Debug)]
pub struct DataStats {
    p: usize,
    n: usize,
    max_abs: f64,
    max_col_norm2: f64,
    row_norm2: Vec<f64>,
    row_pow4: Vec<f64>,
    frob2: f64,
}

impl DataStats {
    /// Fresh accumulator for dimension `p`.
    pub fn new(p: usize) -> Self {
        DataStats {
            p,
            n: 0,
            max_abs: 0.0,
            max_col_norm2: 0.0,
            row_norm2: vec![0.0; p],
            row_pow4: vec![0.0; p],
            frob2: 0.0,
        }
    }

    /// Accumulate one dense chunk (columns are samples).
    pub fn accumulate(&mut self, x: &Mat) {
        assert_eq!(x.rows(), self.p);
        for j in 0..x.cols() {
            let col = x.col(j);
            let mut cn = 0.0;
            for (i, &v) in col.iter().enumerate() {
                let a = v.abs();
                if a > self.max_abs {
                    self.max_abs = a;
                }
                let v2 = v * v;
                cn += v2;
                self.row_norm2[i] += v2;
                self.row_pow4[i] += v2 * v2;
            }
            if cn > self.max_col_norm2 {
                self.max_col_norm2 = cn;
            }
            self.frob2 += cn;
        }
        self.n += x.cols();
    }

    /// Samples seen so far.
    pub fn n(&self) -> usize {
        self.n
    }

    /// `‖X‖max` — max absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.max_abs
    }

    /// `‖X‖max-col` — max column l2 norm.
    pub fn max_col_norm(&self) -> f64 {
        self.max_col_norm2.sqrt()
    }

    /// `‖X‖max-row` — max row l2 norm.
    pub fn max_row_norm(&self) -> f64 {
        self.row_norm2.iter().fold(0.0f64, |m, &v| m.max(v)).sqrt()
    }

    /// `‖X‖F²`.
    pub fn frob2(&self) -> f64 {
        self.frob2
    }

    /// `max_j Σ_i X_{j,i}⁴` (Eq. 26 last term).
    pub fn max_row_pow4(&self) -> f64 {
        self.row_pow4.iter().fold(0.0f64, |m, &v| m.max(v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn tau_regimes() {
        assert_eq!(tau(10, 100), 9.0); // m/p <= 0.5 -> p/m - 1
        assert_eq!(tau(60, 100), 1.0); // m/p > 0.5 -> 1
        assert_eq!(tau(50, 100), 1.0); // exactly 0.5 -> p/m-1 = 1
    }

    #[test]
    fn bernstein_invert_roundtrip() {
        // forward tail at the returned t should equal delta
        let (sigma2, l, pref, delta) = (0.3, 0.05, 200.0, 1e-3);
        let t = bernstein_invert(sigma2, l, pref, delta);
        let back = pref * (-(t * t) / 2.0 / (sigma2 + l * t / 3.0)).exp();
        assert!((back - delta).abs() / delta < 1e-9, "back={back}");
    }

    #[test]
    fn bernstein_invert_vacuous_tail_returns_zero() {
        // documented degenerate case: delta >= prefactor makes the tail
        // constraint vacuous and the inverted bound collapses to t = 0
        assert_eq!(bernstein_invert(0.3, 0.05, 1.0, 1.0), 0.0);
        assert_eq!(bernstein_invert(0.3, 0.05, 1.0, 2.0), 0.0);
        // just inside the meaningful regime the bound is positive
        assert!(bernstein_invert(0.3, 0.05, 1.0, 0.999) > 0.0);
    }

    #[test]
    #[should_panic(expected = "delta and prefactor must be positive")]
    fn bernstein_invert_rejects_nonpositive_delta() {
        bernstein_invert(0.3, 0.05, 1.0, 0.0);
    }

    #[test]
    #[should_panic(expected = "m must be positive")]
    fn tau_rejects_zero_m() {
        tau(0, 100);
    }

    #[test]
    #[should_panic(expected = "m must be positive")]
    fn center_error_bound_rejects_zero_m() {
        center_error_bound(64, 0, 10, 1e-2);
    }

    #[test]
    #[should_panic(expected = "non-empty cluster")]
    fn center_error_bound_rejects_empty_cluster() {
        center_error_bound(64, 8, 0, 1e-2);
    }

    #[test]
    fn center_error_bound_is_finite_and_monotone_in_cluster_size() {
        let small = center_error_bound(512, 26, 10, 1e-2);
        let large = center_error_bound(512, 26, 10_000, 1e-2);
        assert!(small.is_finite() && large.is_finite());
        assert!(large < small, "more members must tighten the bound");
    }

    #[test]
    fn corollary5_values_from_paper() {
        // Paper: p=512, eta=1, t=0.01 -> 137.2, 15.1, 1.6 for n=1e5,1e6,1e7.
        let cases = [(1e5, 137.2), (1e6, 15.1), (1e7, 1.6)];
        for (n, want) in cases {
            let got = corollary5_min_m(512, n as usize, 0.01, 1.0);
            assert!(
                (got - want).abs() / want < 0.05,
                "n={n}: got {got:.3} want {want}"
            );
        }
    }

    #[test]
    fn rho_clipped_at_one() {
        assert_eq!(rho_preconditioned(100, 100, 10, 1.0, 0.01), 1.0);
        let rho = rho_preconditioned(10, 1000, 1000, 1.0, 0.01);
        assert!(rho < 1.0 && rho > 0.0);
    }

    #[test]
    fn data_stats_match_mat_norms() {
        let mut rng = Pcg64::seed(3);
        let x = Mat::from_fn(20, 50, |_, _| rng.normal());
        let mut st = DataStats::new(20);
        // stream in two chunks
        st.accumulate(&x.col_range(0, 30));
        st.accumulate(&x.col_range(30, 50));
        assert_eq!(st.n(), 50);
        assert!((st.max_abs() - x.max_abs()).abs() < 1e-12);
        assert!((st.max_col_norm() - x.max_col_norm()).abs() < 1e-12);
        assert!((st.max_row_norm() - x.max_row_norm()).abs() < 1e-12);
        assert!((st.frob2() - x.frob_norm().powi(2)).abs() < 1e-9);
        // max row 4th moment vs direct
        let mut want = 0.0f64;
        for i in 0..20 {
            let s: f64 = (0..50).map(|j| x.get(i, j).powi(4)).sum();
            want = want.max(s);
        }
        assert!((st.max_row_pow4() - want).abs() < 1e-9);
    }
}
