//! Theorem 4: the unbiased sample-mean estimator from sparsified data.
//!
//! `x̂̄_n = (p/m) (1/n) Σ R_i R_iᵀ x_i` — streaming accumulation over
//! [`SparseChunk`]s, plus the paper's explicit ℓ∞ error bound (Eq. 16).

use crate::estimators::bounds::{bernstein_invert, tau};
use crate::sparse::SparseChunk;

/// Streaming unbiased mean estimator (Theorem 4, Eq. 8).
#[derive(Clone, Debug)]
pub struct SparseMeanEstimator {
    p: usize,
    m: usize,
    sum: Vec<f64>,
    n: usize,
    /// Scheme-supplied override of the Eq. 8 `p/m` rescale. `None` keeps
    /// the uniform-scheme default; weighted schemes
    /// (`sampling::Scheme::Hybrid`) store inverse-probability-scaled
    /// slots whose scatter-add is already an unbiased sketch, so they
    /// pass `Some(1.0)`.
    scale: Option<f64>,
}

impl SparseMeanEstimator {
    /// Fresh estimator for chunks of shape `(p, m)` from a uniform
    /// sampling scheme (the Eq. 8 `p/m` rescale).
    pub fn new(p: usize, m: usize) -> Self {
        SparseMeanEstimator { p, m, sum: vec![0.0; p], n: 0, scale: None }
    }

    /// Override the per-sum rescale (before the `1/n`); weighted schemes
    /// pass `1.0`.
    pub fn with_scale(mut self, scale: f64) -> Self {
        self.scale = Some(scale);
        self
    }

    /// Fold one sparsified chunk into the running sums.
    pub fn accumulate(&mut self, chunk: &SparseChunk) {
        assert_eq!(chunk.p(), self.p, "chunk p mismatch");
        assert_eq!(chunk.m(), self.m, "chunk m mismatch");
        for i in 0..chunk.n() {
            for (idx, val) in chunk.col_indices(i).iter().zip(chunk.col_values(i)) {
                self.sum[*idx as usize] += *val;
            }
        }
        self.n += chunk.n();
    }

    /// Samples seen so far.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The estimate `x̂̄_n` (Eq. 8, or the scheme-supplied rescale).
    /// Panics if no samples were accumulated.
    pub fn estimate(&self) -> Vec<f64> {
        assert!(self.n > 0, "no samples accumulated");
        let scale = match self.scale {
            Some(s) => s / self.n as f64,
            None => (self.p as f64 / self.m as f64) / self.n as f64,
        };
        self.sum.iter().map(|s| s * scale).collect()
    }

    /// Merge a partner accumulator (distributed / multi-worker reduction).
    /// Both sides must use the same rescale calibration — merging a
    /// weighted (scale-1) partition into a uniform (`p/m`) one would
    /// silently mis-scale every sum that came from it.
    pub fn merge(&mut self, other: &SparseMeanEstimator) {
        assert_eq!(self.p, other.p);
        assert_eq!(self.m, other.m);
        assert_eq!(self.scale, other.scale, "cannot merge mixed mean calibrations");
        for (a, b) in self.sum.iter_mut().zip(&other.sum) {
            *a += b;
        }
        self.n += other.n;
    }

    /// `(p, m)` the estimator was built for.
    pub(crate) fn shape(&self) -> (usize, usize) {
        (self.p, self.m)
    }

    /// The scheme-supplied rescale override, if any.
    pub(crate) fn scale_opt(&self) -> Option<f64> {
        self.scale
    }

    /// Raw coordinate sums (before any rescale) — the serializable state.
    pub(crate) fn sum_raw(&self) -> &[f64] {
        &self.sum
    }

    /// Rebuild from serialized state (the `distributed` codec).
    pub(crate) fn from_raw(
        p: usize,
        m: usize,
        scale: Option<f64>,
        sum: Vec<f64>,
        n: usize,
    ) -> Self {
        assert_eq!(sum.len(), p, "mean state length mismatch");
        SparseMeanEstimator { p, m, sum, n, scale }
    }
}

/// Data-dependent inputs to the Theorem 4 bound. Obtain from
/// [`DataStats`](super::DataStats) over the *preconditioned* data, or from
/// matrix norms directly in small experiments.
#[derive(Clone, Copy, Debug)]
pub struct MeanBoundInputs {
    /// `‖X‖max` of the (preconditioned) data actually sampled.
    pub max_abs: f64,
    /// `‖X‖max-row` of the same matrix.
    pub max_row_norm: f64,
    /// Number of samples n.
    pub n: usize,
    /// Ambient dimension p.
    pub p: usize,
    /// Kept entries per sample m.
    pub m: usize,
}

impl MeanBoundInputs {
    /// The ℓ∞ error bound `t` at failure probability `δ₁` — Eq. (16).
    pub fn t_for_delta(&self, delta1: f64) -> f64 {
        let nf = self.n as f64;
        // Bernstein with sigma² = (p/m − 1)·‖X‖max-row²/n², L = τ·‖X‖max/n,
        // prefactor 2p (union bound over p coordinates).
        let sigma2 =
            (self.p as f64 / self.m as f64 - 1.0) * self.max_row_norm.powi(2) / (nf * nf);
        let l = tau(self.m, self.p) * self.max_abs / nf;
        bernstein_invert(sigma2, l, 2.0 * self.p as f64, delta1)
    }

    /// Failure probability δ₁ at error level `t` — Eq. (10).
    pub fn delta_for_t(&self, t: f64) -> f64 {
        let nf = self.n as f64;
        let denom = (self.p as f64 / self.m as f64 - 1.0) * self.max_row_norm.powi(2) / nf
            + tau(self.m, self.p) * self.max_abs * t / 3.0;
        (2.0 * self.p as f64) * (-(nf * t * t) / 2.0 / denom).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;
    use crate::sampling::{Sparsifier, SparsifyConfig};
    use crate::transform::TransformKind;

    fn linf(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn unbiased_without_preconditioning() {
        // Accumulate masked raw data; estimator must converge to the true
        // sample mean (no ROS involved — pure Thm 4 setting).
        let (p, n, m) = (32usize, 20_000usize, 8usize);
        let mut rng = Pcg64::seed(5);
        let xbar: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
        let x = Mat::from_fn(p, n, |i, _| xbar[i] + 0.5 * rng.normal());
        let cfg = SparsifyConfig { gamma: m as f64 / p as f64, transform: TransformKind::Hadamard, seed: 77 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let chunk = sp.compress_chunk_no_precondition(&x, 0).unwrap();
        let mut est = SparseMeanEstimator::new(p, m);
        est.accumulate(&chunk);
        let got = est.estimate();
        let truth = x.col_mean();
        assert!(linf(&got, &truth) < 0.15, "err {}", linf(&got, &truth));
    }

    #[test]
    fn merge_equals_single_pass() {
        let (p, m) = (16usize, 4usize);
        let mut rng = Pcg64::seed(9);
        let x = Mat::from_fn(p, 40, |_, _| rng.normal());
        let cfg = SparsifyConfig { gamma: 0.25, transform: TransformKind::Hadamard, seed: 3 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let whole = sp.compress_chunk(&x, 0).unwrap();
        let mut single = SparseMeanEstimator::new(p, m);
        single.accumulate(&whole);

        let mut a = SparseMeanEstimator::new(p, m);
        let mut b = SparseMeanEstimator::new(p, m);
        a.accumulate(&sp.compress_chunk(&x.col_range(0, 25), 0).unwrap());
        b.accumulate(&sp.compress_chunk(&x.col_range(25, 40), 25).unwrap());
        a.merge(&b);
        assert!(linf(&a.estimate(), &single.estimate()) < 1e-12);
    }

    #[test]
    fn error_shrinks_with_n() {
        let p = 64;
        let mut rng = Pcg64::seed(11);
        let xbar: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
        let cfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 1 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let mut errs = Vec::new();
        for &n in &[500usize, 5_000, 50_000] {
            let x = Mat::from_fn(p, n, |i, _| xbar[i] + rng.normal());
            let y = sp.precondition_dense(&x);
            let chunk = sp.compress_chunk(&x, 0).unwrap();
            let mut est = SparseMeanEstimator::new(sp.p(), sp.m());
            est.accumulate(&chunk);
            errs.push(linf(&est.estimate(), &y.col_mean()));
        }
        assert!(errs[2] < errs[0], "errors must decrease: {errs:?}");
    }

    #[test]
    fn hybrid_mean_is_unbiased_with_unit_scale() {
        // Weighted (hybrid) chunks are unbiased sketches: the mean
        // estimator with scale 1 (not p/m) must converge to the plain
        // sample mean of the raw data. Monte Carlo over scheme seeds with
        // a self-calibrated tolerance.
        use crate::sampling::Scheme;
        let (p, n, trials) = (16usize, 8usize, 6000usize);
        let mut rng = Pcg64::seed(33);
        let x = Mat::from_fn(p, n, |_, _| rng.normal());
        let truth = x.col_mean();
        let mut sum = vec![0.0f64; p];
        let mut sumsq = vec![0.0f64; p];
        for t in 0..trials {
            let cfg = SparsifyConfig {
                gamma: 0.25,
                transform: TransformKind::Hadamard,
                seed: 40_000 + t as u64,
            };
            let sp = Sparsifier::with_scheme(p, cfg, Scheme::Hybrid).unwrap();
            let chunk = sp.compress_chunk(&x, 0).unwrap();
            let mut est = SparseMeanEstimator::new(sp.p(), sp.m()).with_scale(1.0);
            est.accumulate(&chunk);
            for (j, v) in est.estimate().into_iter().enumerate() {
                sum[j] += v;
                sumsq[j] += v * v;
            }
        }
        let tf = trials as f64;
        for j in 0..p {
            let mean = sum[j] / tf;
            let var = (sumsq[j] / tf - mean * mean).max(0.0);
            let se = (var / tf).sqrt();
            assert!(
                (mean - truth[j]).abs() <= 6.0 * se + 1e-9,
                "coord {j}: mean {mean} vs truth {} (se {se})",
                truth[j]
            );
        }
    }

    #[test]
    fn bound_formula_matches_tail_inversion() {
        let b = MeanBoundInputs { max_abs: 0.3, max_row_norm: 4.0, n: 1000, p: 100, m: 30 };
        let t = b.t_for_delta(1e-3);
        let back = b.delta_for_t(t);
        assert!((back - 1e-3).abs() / 1e-3 < 1e-6, "δ roundtrip: {back}");
    }

    #[test]
    fn bound_dominates_empirical_error() {
        // Thm 4 at δ₁=0.001 must dominate the max error over many runs.
        let (p, n) = (64usize, 2000usize);
        let mut rng = Pcg64::seed(13);
        let xbar: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
        let x = Mat::from_fn(p, n, |i, _| xbar[i] + rng.normal());
        let mut worst = 0.0f64;
        let mut inputs = None;
        for run in 0..30 {
            let cfg = SparsifyConfig {
                gamma: 0.3,
                transform: TransformKind::Hadamard,
                seed: 1000 + run,
            };
            let sp = Sparsifier::new(p, cfg).unwrap();
            let y = sp.precondition_dense(&x);
            let chunk = sp.compress_chunk(&x, 0).unwrap();
            let mut est = SparseMeanEstimator::new(sp.p(), sp.m());
            est.accumulate(&chunk);
            worst = worst.max(linf(&est.estimate(), &y.col_mean()));
            if inputs.is_none() {
                inputs = Some(MeanBoundInputs {
                    max_abs: y.max_abs(),
                    max_row_norm: y.max_row_norm(),
                    n,
                    p,
                    m: sp.m(),
                });
            }
        }
        let t = inputs.unwrap().t_for_delta(1e-3);
        assert!(worst <= t, "empirical max {worst} exceeded bound {t}");
        // ...and the bound should be within an order of magnitude (tightness)
        assert!(t < 20.0 * worst, "bound too loose: {t} vs {worst}");
    }
}
