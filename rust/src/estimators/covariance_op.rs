//! The Theorem 6 covariance estimate as an *implicit* operator.
//!
//! [`CovarianceEstimator`](super::CovarianceEstimator) materializes the
//! p×p scatter; for the PCA arm that matrix only ever feeds a top-k
//! eigensolve, which the block-Krylov solver
//! ([`linalg::block_krylov_topk`](crate::linalg::block_krylov_topk))
//! drives through block products alone. This module evaluates that
//! product directly from sparsified chunks:
//!
//! `Ĉ_n · B = c₁ · W (Wᵀ B) − c₂ · diag(W Wᵀ) ∘ B`
//!
//! where `W` is the p×n sparse sample matrix (m kept entries per
//! column), `c₁ = p(p−1)/(m(m−1))/n` is the Eq. 19 rescale and
//! `c₂ = c₁·(p−m)/(p−1)` the Eq. 21 diagonal unbiasing — the exact same
//! estimate [`CovarianceEstimator::estimate`](super::CovarianceEstimator::estimate)
//! materializes, applied in O(n·m·b) flops and O(p·b) memory with **no
//! p×p allocation**.
//!
//! Parallelism follows the PR 1 contract (deterministic range partition +
//! in-order per-cell accumulation): the dot phase `D = Wᵀ B` partitions
//! *samples* (each output column is computed by exactly one worker, pure
//! per sample), the scatter phase `G·B += W·D` partitions the *output
//! rows* (each cell accumulates its contributions in global sample order
//! via the same sorted-index binary search as the K-means center update).
//! Results are therefore bitwise invariant to the worker count **and** to
//! chunk granularity — a store reader's memory budget changes chunk
//! boundaries, never bits.

use std::ops::Range;

use crate::error::{invalid, Result};
use crate::linalg::{Mat, SymOp};
use crate::parallel;
use crate::sparse::SparseChunk;

/// Streaming accumulator for `diag(W Wᵀ)` (a p-vector) and the sample
/// count — the only whole-pass statistics the implicit operator needs.
/// Accumulation is serial in sample order, so the result is independent
/// of chunk boundaries.
///
/// The sum runs over *slots*, so on weighted with-replacement chunks
/// (`sampling::Scheme::Hybrid`, duplicate indices allowed) it yields the
/// per-slot squares `S` — exactly the diagonal correction the weighted
/// covariance calibration needs (see [`SparseCovOp::new_weighted`]).
#[derive(Clone, Debug)]
pub struct ScatterDiag {
    diag: Vec<f64>,
    n: usize,
}

impl ScatterDiag {
    /// Fresh accumulator for chunks of dimension `p`.
    pub fn new(p: usize) -> Self {
        ScatterDiag { diag: vec![0.0; p], n: 0 }
    }

    /// Fold one chunk: `diag[j] += w²` over every kept entry.
    pub fn accumulate(&mut self, chunk: &SparseChunk) {
        assert_eq!(chunk.p(), self.diag.len(), "chunk p mismatch");
        for i in 0..chunk.n() {
            for (&j, &v) in chunk.col_indices(i).iter().zip(chunk.col_values(i)) {
                self.diag[j as usize] += v * v;
            }
        }
        self.n += chunk.n();
    }

    /// Samples seen so far.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The accumulated diagonal of the raw scatter `W Wᵀ` (unscaled).
    pub fn diag(&self) -> &[f64] {
        &self.diag
    }
}

/// The Eq. 19/21 scale pair `(c₁, c₂)`: `Ĉ_n = c₁·G − c₂·diag(G)` for the
/// raw scatter `G = W Wᵀ` under **uniform** (without-replacement)
/// sampling.
pub(crate) fn unbias_scales(p: usize, m: usize, n: usize) -> (f64, f64) {
    debug_assert!(m >= 2 && n > 0);
    let (pf, mf) = (p as f64, m as f64);
    let c1 = pf * (pf - 1.0) / (mf * (mf - 1.0)) / n as f64;
    let c2 = c1 * (pf - mf) / (pf - 1.0);
    (c1, c2)
}

/// The scale pair for **weighted with-replacement** schemes
/// (`sampling::Scheme::Hybrid`): with `S = diag(ΣΣ u²)` the per-slot
/// squares (exactly what [`ScatterDiag`] accumulates over weighted
/// chunks), `Ĉ = c·(G − S)` with `c = m/((m−1)·n)` is exactly unbiased —
/// both constants of the shared `c₁·G − c₂·diag` kernel collapse to `c`.
/// See `sampling::scheme` for the derivation.
pub(crate) fn weighted_scales(m: usize, n: usize) -> (f64, f64) {
    debug_assert!(m >= 2 && n > 0);
    let mf = m as f64;
    let c = mf / (mf - 1.0) / n as f64;
    (c, c)
}

/// Below this many columns the fork overhead beats the scatter work;
/// run the chunk serially (bitwise identical either way).
const MIN_SCATTER_COLS: usize = 256;

/// Fold one chunk's contribution into `gt = (W Wᵀ B)ᵀ` (b×p,
/// accumulated across calls). `bt` is the transposed block `Bᵀ` (b×p) —
/// both transposed so every per-index access is a contiguous b-vector.
pub(crate) fn scatter_chunk(chunk: &SparseChunk, bt: &Mat, gt: &mut Mat, workers: usize) {
    let b = bt.rows();
    let p = bt.cols();
    debug_assert_eq!(chunk.p(), p);
    debug_assert_eq!((gt.rows(), gt.cols()), (b, p));
    let nc = chunk.n();
    if nc == 0 {
        return;
    }
    let workers = if nc < MIN_SCATTER_COLS { 1 } else { workers.max(1) };
    // one ISA decision per chunk — both phases run the crate::simd
    // dot/scatter kernels, whose tiers are bitwise identical, so the
    // partition-invariance argument below is unaffected by dispatch
    let isa = crate::simd::active();
    // phase 1 — Dᵀ (b×nc): column i holds d_i = Σ_t w_t · Bᵀ[:, idx_t].
    // Sample-partitioned; each column is computed by exactly one worker
    // with a pure per-sample kernel, so the values are partition-free.
    let mut dt = Mat::zeros(b, nc);
    {
        let ranges = parallel::split_ranges(nc, workers);
        let panels = parallel::split_col_panels(dt.as_mut_slice(), b, &ranges);
        let jobs: Vec<_> = ranges.into_iter().zip(panels).collect();
        let bts = bt.as_slice();
        parallel::run_panel_jobs(jobs, |r: Range<usize>, panel: &mut [f64]| {
            for (local, i) in r.enumerate() {
                let dcol = &mut panel[local * b..(local + 1) * b];
                crate::simd::col_dot(isa, dcol, chunk.col_indices(i), chunk.col_values(i), bts);
            }
        });
    }
    // phase 2 — gt[:, j] += Σ_i w_{j,i} · d_i, output-row partitioned
    // (columns of the transposed gt): worker t owns a contiguous column
    // panel and walks all samples in order, locating its slice of each
    // sorted index list by binary search — every cell accumulates in
    // global sample order regardless of the partition.
    {
        let ranges = parallel::split_ranges(p, workers);
        let panels = parallel::split_col_panels(gt.as_mut_slice(), b, &ranges);
        let jobs: Vec<_> = ranges.into_iter().zip(panels).collect();
        let dt = &dt;
        parallel::run_panel_jobs(jobs, |r: Range<usize>, panel: &mut [f64]| {
            let (lo, hi) = (r.start as u32, r.end as u32);
            for i in 0..nc {
                let idx = chunk.col_indices(i);
                let val = chunk.col_values(i);
                let a_lo = idx.partition_point(|&j| j < lo);
                let a_hi = a_lo + idx[a_lo..].partition_point(|&j| j < hi);
                if a_lo == a_hi {
                    continue;
                }
                let dcol = dt.col(i);
                crate::simd::col_scatter(
                    isa,
                    panel,
                    &idx[a_lo..a_hi],
                    &val[a_lo..a_hi],
                    lo,
                    dcol,
                );
            }
        });
    }
}

/// Assemble the estimate's action from the accumulated transposed
/// product: `out[j, l] = c₁·gt[l, j] − c₂·diag[j]·block[j, l]`.
pub(crate) fn finish_apply(block: &Mat, gt: &Mat, c1: f64, c2: f64, diag: &[f64]) -> Mat {
    let p = block.rows();
    let b = block.cols();
    debug_assert_eq!((gt.rows(), gt.cols()), (b, p));
    debug_assert_eq!(diag.len(), p);
    let mut out = Mat::zeros(p, b);
    for l in 0..b {
        let bcol = block.col(l);
        let ocol = out.col_mut(l);
        for j in 0..p {
            ocol[j] = c1 * gt.get(l, j) - c2 * diag[j] * bcol[j];
        }
    }
    out
}

/// The Theorem 6 covariance estimate over in-memory sparsified chunks,
/// as a [`SymOp`] — the covariance-free backend of
/// [`Pca::from_sparse_operator`](crate::pca::Pca::from_sparse_operator).
///
/// Chunks must share one `(p, m)` shape and should be in global column
/// order (the drivers sort) so results are bit-for-bit reproducible.
/// Construction makes one cheap pass to accumulate `diag(W Wᵀ)` and the
/// sample count; every [`apply`](SymOp::apply) is then one pass over the
/// chunks.
///
/// # Example
///
/// ```
/// use pds::estimators::SparseCovOp;
/// use pds::linalg::{block_krylov_topk, Mat, SymOp};
/// use pds::rng::Pcg64;
/// use pds::sampling::{Sparsifier, SparsifyConfig};
/// use pds::transform::TransformKind;
///
/// let cfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 3 };
/// let sp = Sparsifier::new(16, cfg)?;
/// let mut rng = Pcg64::seed(1);
/// let x = Mat::from_fn(16, 40, |_, _| rng.normal());
/// let chunks = [sp.compress_chunk(&x, 0)?];
///
/// let mut op = SparseCovOp::new(&chunks, 1)?;
/// assert_eq!(op.dim(), 16);
/// let (vals, vecs) = block_krylov_topk(&mut op, 2, 30, 7)?;
/// assert_eq!((vecs.rows(), vecs.cols()), (16, 2));
/// assert!(vals[0] >= vals[1]);
/// # Ok::<(), pds::Error>(())
/// ```
pub struct SparseCovOp<'a> {
    chunks: &'a [SparseChunk],
    p: usize,
    c1: f64,
    c2: f64,
    diag: Vec<f64>,
    workers: usize,
}

impl<'a> SparseCovOp<'a> {
    /// Build the operator over **uniform-scheme** chunks with a fork/join
    /// width of `workers` per block product (any width yields identical
    /// bits).
    pub fn new(chunks: &'a [SparseChunk], workers: usize) -> Result<Self> {
        Self::build(chunks, workers, false)
    }

    /// Build the operator over **weighted with-replacement** chunks
    /// (`sampling::Scheme::Hybrid`): same kernels, the weighted
    /// `c₁ = c₂ = m/((m−1)·n)` calibration — the accumulated per-slot
    /// diagonal *is* the correction term, so `apply` evaluates the
    /// exactly unbiased cross-slot estimate.
    pub fn new_weighted(chunks: &'a [SparseChunk], workers: usize) -> Result<Self> {
        Self::build(chunks, workers, true)
    }

    fn build(chunks: &'a [SparseChunk], workers: usize, weighted: bool) -> Result<Self> {
        let Some(first) = chunks.first() else {
            return invalid("SparseCovOp: no chunks");
        };
        let (p, m) = (first.p(), first.m());
        if m < 2 {
            return invalid("SparseCovOp needs m >= 2 (Eq. 19 rescale)");
        }
        if chunks.iter().any(|c| c.p() != p || c.m() != m) {
            return invalid("SparseCovOp: mixed chunk shapes");
        }
        let mut stats = ScatterDiag::new(p);
        for c in chunks {
            stats.accumulate(c);
        }
        if stats.n() == 0 {
            return invalid("SparseCovOp: no samples");
        }
        let (c1, c2) = if weighted {
            weighted_scales(m, stats.n())
        } else {
            unbias_scales(p, m, stats.n())
        };
        let diag = stats.diag().to_vec();
        Ok(SparseCovOp { chunks, p, c1, c2, diag, workers: workers.max(1) })
    }
}

impl SymOp for SparseCovOp<'_> {
    fn dim(&self) -> usize {
        self.p
    }

    fn apply(&mut self, block: &Mat) -> Result<Mat> {
        assert_eq!(block.rows(), self.p, "SparseCovOp: block rows != p");
        let bt = block.transpose();
        let mut gt = Mat::zeros(block.cols(), self.p);
        for chunk in self.chunks {
            scatter_chunk(chunk, &bt, &mut gt, self.workers);
        }
        Ok(finish_apply(block, &gt, self.c1, self.c2, &self.diag))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimators::CovarianceEstimator;
    use crate::linalg::DenseSymOp;
    use crate::testing::fixtures::{randmat, sparse_chunk};
    use crate::testing::prop::forall;

    /// Split one chunk into contiguous pieces of `cols` columns — the
    /// memory-budget shape a store reader would hand out.
    fn split_chunk(chunk: &SparseChunk, cols: usize) -> Vec<SparseChunk> {
        let mut out = Vec::new();
        let mut a = 0usize;
        while a < chunk.n() {
            let b = (a + cols).min(chunk.n());
            let (m, n) = (chunk.m(), b - a);
            out.push(
                SparseChunk::from_raw(
                    chunk.p(),
                    m,
                    n,
                    chunk.indices()[a * m..b * m].to_vec(),
                    chunk.values()[a * m..b * m].to_vec(),
                    chunk.start_col() + a,
                )
                .unwrap(),
            );
            a = b;
        }
        out
    }

    #[test]
    fn apply_matches_explicit_dense_estimate() {
        // property: op.apply(B) == CovarianceEstimator::estimate() · B
        // (the materialized Thm 6 matrix) on random chunks and blocks
        forall("cov_op_vs_dense", 15, |g| {
            let p = g.int(4, 40) as usize;
            let m = g.int(2, p as i64) as usize;
            let n = g.int(1, 60) as usize;
            let b = g.int(1, 6) as usize;
            let seed = g.int(0, 1 << 40) as u64;
            let chunk = sparse_chunk(p, m, n, 0, seed);
            let block = randmat(p, b, seed ^ 0x5A5A);

            let mut est = CovarianceEstimator::new(p, m);
            est.accumulate(&chunk);
            let dense = est.estimate();
            let want = dense.matmul(&block);

            let chunks = [chunk];
            let mut op = SparseCovOp::new(&chunks, 1).unwrap();
            let got = op.apply(&block).unwrap();
            let scale = want.max_abs().max(1.0);
            assert!(
                got.sub(&want).max_abs() / scale < 1e-9,
                "case {}: |op - dense| = {}",
                g.case,
                got.sub(&want).max_abs()
            );

            // and the dense operator wrapper agrees too (sanity of the
            // test itself)
            let mut dop = DenseSymOp::new(&dense);
            let via_dense = dop.apply(&block).unwrap();
            assert!(via_dense.sub(&want).max_abs() == 0.0);
        });
    }

    #[test]
    fn apply_is_bitwise_invariant_to_workers_and_chunking() {
        let p = 48;
        let m = 9;
        let n = 700;
        let whole = sparse_chunk(p, m, n, 0, 31);
        let block = randmat(p, 5, 77);
        let chunks = [whole.clone()];
        let mut base_op = SparseCovOp::new(&chunks, 1).unwrap();
        let base = base_op.apply(&block).unwrap();
        for workers in [2usize, 4, 7] {
            for cols in [64usize, 257, 1000] {
                let pieces = split_chunk(&whole, cols);
                let mut op = SparseCovOp::new(&pieces, workers).unwrap();
                let got = op.apply(&block).unwrap();
                for (a, b) in got.as_slice().iter().zip(base.as_slice()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "workers={workers} cols={cols}");
                }
            }
        }
    }

    #[test]
    fn weighted_apply_matches_dense_weighted_estimate() {
        // hybrid (weighted, duplicate-slot) chunks: the implicit operator
        // with the weighted calibration must act exactly like the dense
        // weighted estimator's materialized matrix
        use crate::rng::Pcg64;
        use crate::sampling::{Scheme, Sparsifier, SparsifyConfig};
        use crate::transform::TransformKind;
        forall("weighted_cov_op_vs_dense", 10, |g| {
            let p = 1usize << g.int(3, 5); // 8..32, pow2 so p_work == p
            let n = g.int(2, 40) as usize;
            let b = g.int(1, 5) as usize;
            let seed = g.int(0, 1 << 40) as u64;
            let cfg = SparsifyConfig {
                gamma: g.float(0.2, 0.8),
                transform: TransformKind::Hadamard,
                seed,
            };
            let sp = Sparsifier::with_scheme(p, cfg, Scheme::Hybrid).unwrap();
            let mut rng = Pcg64::seed(seed ^ 0x77);
            let x = crate::linalg::Mat::from_fn(p, n, |_, _| rng.normal());
            let chunk = sp.compress_chunk(&x, 0).unwrap();
            chunk.validate_weighted().unwrap();
            let block = randmat(p, b, seed ^ 0x1234);

            let mut est = CovarianceEstimator::new_weighted(p, sp.m());
            est.accumulate(&chunk);
            let want = est.estimate().matmul(&block);

            let chunks = [chunk];
            let mut op = SparseCovOp::new_weighted(&chunks, 1).unwrap();
            let got = op.apply(&block).unwrap();
            let scale = want.max_abs().max(1.0);
            assert!(
                got.sub(&want).max_abs() / scale < 1e-9,
                "case {}: |op - dense| = {}",
                g.case,
                got.sub(&want).max_abs()
            );
        });
    }

    #[test]
    fn scatter_diag_is_chunk_granularity_independent() {
        let whole = sparse_chunk(24, 5, 100, 0, 3);
        let mut one = ScatterDiag::new(24);
        one.accumulate(&whole);
        let mut many = ScatterDiag::new(24);
        for piece in split_chunk(&whole, 17) {
            many.accumulate(&piece);
        }
        assert_eq!(one.n(), many.n());
        for (a, b) in one.diag().iter().zip(many.diag()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(SparseCovOp::new(&[], 1).is_err());
        let a = sparse_chunk(16, 4, 3, 0, 1);
        let b = sparse_chunk(16, 5, 3, 3, 2);
        let both = [a.clone(), b];
        assert!(SparseCovOp::new(&both, 1).is_err(), "mixed m must be rejected");
        let thin = sparse_chunk(16, 1, 3, 0, 1);
        let chunks = [thin];
        assert!(SparseCovOp::new(&chunks, 1).is_err(), "m < 2 must be rejected");
        let ok = [a];
        assert!(SparseCovOp::new(&ok, 1).is_ok());
    }
}
