//! Unbiased estimators recovered from the sparsified stream, with the
//! paper's finite-sample concentration bounds.
//!
//! * [`SparseMeanEstimator`] — Theorem 4 (ℓ∞/ℓ2 error, failure prob. Eq. 10,
//!   explicit bound Eq. 16, Corollary 5 sample-size law).
//! * [`CovarianceEstimator`] — Theorem 6 (Eqs. 19–26: unbiasing, L, σ²,
//!   spectral-norm bound).
//! * [`SparseCovOp`] / [`ScatterDiag`] — the same Theorem 6 estimate as
//!   an *implicit* operator (`Ĉ_n · B` straight from the chunks, no p×p
//!   materialization) for the covariance-free block-Krylov PCA path.
//!
//! Every estimator exists in two calibrations selected by the sampling
//! scheme (`sampling::Scheme`): the paper's uniform-sampling constants
//! (default), and the weighted with-replacement calibration for
//! `Scheme::Hybrid` chunks ([`CovarianceEstimator::new_weighted`],
//! [`SparseCovOp::new_weighted`], mean scale `1` via
//! [`SparseMeanEstimator::with_scale`]) — both exactly unbiased for
//! their scheme.
//! * [`HkAccumulator`] — Theorem 7 (conditioning of the center-update
//!   system `H_k μ' = m_k`).
//! * `bounds` (re-exported here) — shared Bernstein machinery +
//!   data-dependent norms, including [`center_error_bound`] (the K-means
//!   per-step center guarantee the `FitPlan` K-means fits evaluate each
//!   Lloyd iteration).

mod bounds;
mod covariance;
mod covariance_op;
mod hk;
mod mean;

pub use bounds::{
    bernstein_invert, center_error_bound, corollary5_min_m, rho_preconditioned, tau, DataStats,
};
pub use covariance::{CovBoundInputs, CovarianceEstimator};
pub use covariance_op::{ScatterDiag, SparseCovOp};
pub use hk::HkAccumulator;
pub use mean::{MeanBoundInputs, SparseMeanEstimator};

pub(crate) use covariance_op::{finish_apply, scatter_chunk, unbias_scales, weighted_scales};
