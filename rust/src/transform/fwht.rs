//! In-place fast Walsh–Hadamard transform, normalized (orthonormal), in
//! Sylvester ordering — bit-for-bit the same transform as the Pallas
//! kernel `python/compile/kernels/fwht.py` and the `ref.fwht_ref` oracle.
//!
//! Perf (§Perf log in `rust/EXPERIMENTS.md`): the transform is
//! cache-blocked. The textbook strided loop sweeps the full vector once
//! per stage (`log2 p` sweeps), which is memory-bound once `p` doubles
//! out of L1 (`p ≥ 4096` at 8 bytes/entry). Here all stages with stride
//! `< FWHT_BLOCK` run to completion inside one L1-resident block before
//! the next block is touched, and the remaining cross-block stages are
//! fused in pairs (radix-4), so a size-`p` transform makes
//! `1 + ⌈log2(p/FWHT_BLOCK)/2⌉` passes over memory instead of `log2 p`.
//! Every butterfly keeps the operand order and rounding of the textbook
//! stage loop, so the output is **bitwise identical** to it (asserted in
//! `bitwise_matches_textbook_reference`) — blocking only reorders
//! butterflies that touch disjoint data.

use crate::simd::Isa;

/// `true` iff `n` is a positive power of two.
#[inline]
pub fn is_pow2(n: usize) -> bool {
    n > 0 && n & (n - 1) == 0
}

/// Intra-block transform size: 1024 f64 = 8 KB, half a typical 32 KB L1d,
/// leaving room for the outer loop's other streams.
pub(crate) const FWHT_BLOCK: usize = 1024;

/// Fused radix-4 first pass: stages h=1 and h=2 in one sweep over
/// 4-aligned quads (`x.len() % 4 == 0`). Bitwise identical to running the
/// two radix-2 stages back to back.
#[inline]
pub(crate) fn radix4_first_pass(x: &mut [f64]) {
    debug_assert_eq!(x.len() % 4, 0);
    let mut i = 0;
    while i < x.len() {
        let (a, b, c, d) = (x[i], x[i + 1], x[i + 2], x[i + 3]);
        let (ab, amb) = (a + b, a - b);
        let (cd, cmd) = (c + d, c - d);
        x[i] = ab + cd;
        x[i + 1] = amb + cmd;
        x[i + 2] = ab - cd;
        x[i + 3] = amb - cmd;
        i += 4;
    }
}

/// One radix-2 stage at stride `h`, outputs scaled by `s`.
#[inline]
fn stage_radix2(x: &mut [f64], h: usize, s: f64) {
    let step = 2 * h;
    let mut base = 0;
    while base < x.len() {
        for i in base..base + h {
            let a = x[i];
            let b = x[i + h];
            x[i] = (a + b) * s;
            x[i + h] = (a - b) * s;
        }
        base += step;
    }
}

/// Two fused radix-2 stages (strides `h` and `2h`) in one sweep, outputs
/// of the second stage scaled by `s`. The intermediate sums/differences
/// are formed exactly as the two separate stages would form them, so the
/// fusion is bitwise identical — it just halves the memory traffic.
#[inline]
fn stage_radix4(x: &mut [f64], h: usize, s: f64) {
    let step = 4 * h;
    let mut base = 0;
    while base < x.len() {
        for i in base..base + h {
            let (x0, x1) = (x[i], x[i + h]);
            let (x2, x3) = (x[i + 2 * h], x[i + 3 * h]);
            // stage h
            let (a, b) = (x0 + x1, x0 - x1);
            let (c, d) = (x2 + x3, x2 - x3);
            // stage 2h
            x[i] = (a + c) * s;
            x[i + h] = (b + d) * s;
            x[i + 2 * h] = (a - c) * s;
            x[i + 3 * h] = (b - d) * s;
        }
        base += step;
    }
}

/// Run stages `from_h, 2·from_h, …, len/2` over all of `x`, pair-fused,
/// folding `scale` into the final stage. Requires `from_h < x.len()`,
/// both powers of two.
fn fwht_stages(x: &mut [f64], from_h: usize, scale: f64) {
    let p = x.len();
    debug_assert!(from_h < p);
    let mut h = from_h;
    // stages are executed in ascending stride order; with an odd count,
    // peel the first as radix-2 so the rest pair up
    let stages = (p / h).trailing_zeros();
    if stages % 2 == 1 {
        stage_radix2(x, h, if 2 * h == p { scale } else { 1.0 });
        h *= 2;
    }
    while h < p {
        debug_assert!(4 * h <= p);
        stage_radix4(x, h, if 4 * h == p { scale } else { 1.0 });
        h *= 4;
    }
}

/// Normalized in-place FWHT over `x` (length must be a power of two).
/// Involutive: applying twice restores the input. O(p log p), with the
/// cache-blocked schedule described in the module docs for large `p`.
///
/// Dispatches on [`crate::simd::active`]; every ISA tier is bitwise
/// identical to the scalar schedule below (see `crate::simd`), so the
/// choice of tier never changes the output.
pub fn fwht_inplace(x: &mut [f64]) {
    fwht_inplace_isa(x, crate::simd::active());
}

/// [`fwht_inplace`] pinned to one ISA tier (used by tests; the public
/// entry dispatches on the active tier). Requests above the detected
/// tier clamp downward.
pub(crate) fn fwht_inplace_isa(x: &mut [f64], isa: Isa) {
    let p = x.len();
    debug_assert!(is_pow2(p), "fwht requires power-of-two length");
    // sizes below one 16-element tile always take the scalar path (the
    // vector schedules need p >= 16); all tiers agree bit for bit anyway
    if p >= 16 {
        match isa {
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 if crate::simd::detect() >= Isa::Avx2 => {
                // SAFETY: AVX2 is detected and p is a power of two >= 16.
                unsafe { crate::simd::x86::fwht_avx2(x) };
                return;
            }
            #[cfg(target_arch = "x86_64")]
            Isa::Sse2 | Isa::Avx2 => {
                crate::simd::x86::fwht_sse2(x);
                return;
            }
            _ => {}
        }
    }
    let scale = 1.0 / (p as f64).sqrt();
    match p {
        1 => {
            x[0] *= scale;
            return;
        }
        2 => {
            let (a, b) = (x[0], x[1]);
            x[0] = (a + b) * scale;
            x[1] = (a - b) * scale;
            return;
        }
        4 => {
            radix4_first_pass(x);
            for v in x.iter_mut() {
                *v *= scale;
            }
            return;
        }
        _ => {}
    }
    if p <= FWHT_BLOCK {
        radix4_first_pass(x);
        fwht_stages(x, 4, scale);
    } else {
        // stages with stride < FWHT_BLOCK stay inside one L1-resident
        // block; finish them block by block before any cross-block stage
        for blk in x.chunks_exact_mut(FWHT_BLOCK) {
            radix4_first_pass(blk);
            fwht_stages(blk, 4, 1.0);
        }
        // remaining cross-block stages (stride >= FWHT_BLOCK)
        fwht_stages(x, FWHT_BLOCK, scale);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    /// The pre-blocking textbook implementation: one radix-2 sweep per
    /// stage, then a normalize pass. The blocked transform must match it
    /// bit for bit.
    fn fwht_textbook(x: &mut [f64]) {
        let p = x.len();
        let mut h = 1;
        while h < p {
            let mut base = 0;
            while base < p {
                for i in base..base + h {
                    let a = x[i];
                    let b = x[i + h];
                    x[i] = a + b;
                    x[i + h] = a - b;
                }
                base += 2 * h;
            }
            h *= 2;
        }
        let s = 1.0 / (p as f64).sqrt();
        for v in x.iter_mut() {
            *v *= s;
        }
    }

    /// Explicit orthonormal Hadamard matrix (test oracle).
    fn hadamard(p: usize) -> Vec<Vec<f64>> {
        let mut h = vec![vec![1.0]];
        while h.len() < p {
            let n = h.len();
            let mut next = vec![vec![0.0; 2 * n]; 2 * n];
            for i in 0..n {
                for j in 0..n {
                    next[i][j] = h[i][j];
                    next[i][j + n] = h[i][j];
                    next[i + n][j] = h[i][j];
                    next[i + n][j + n] = -h[i][j];
                }
            }
            h = next;
        }
        let s = 1.0 / (p as f64).sqrt();
        for row in &mut h {
            for v in row {
                *v *= s;
            }
        }
        h
    }

    /// Entry (i, j) of the unnormalized Sylvester Hadamard matrix:
    /// `(-1)^popcount(i & j)` — the explicit-matrix oracle at sizes where
    /// materializing `hadamard(p)` is too large.
    fn hadamard_sign(i: usize, j: usize) -> f64 {
        if (i & j).count_ones() % 2 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    #[test]
    fn matches_explicit_matrix() {
        for p in [2usize, 4, 8, 32, 128] {
            let mut rng = Pcg64::seed(p as u64);
            let x: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
            let h = hadamard(p);
            let want: Vec<f64> =
                (0..p).map(|i| (0..p).map(|j| h[i][j] * x[j]).sum()).collect();
            let mut got = x.clone();
            fwht_inplace(&mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-10, "p={p}");
            }
        }
    }

    #[test]
    fn blocked_matches_explicit_matrix_large() {
        // the blocked schedule only engages for p > FWHT_BLOCK; pin it
        // against the explicit Sylvester matrix at p = 2^10 (every row)
        // and p = 2^14 (a stratified row subset — the full 2^14 × 2^14
        // matrix would be 2 GiB).
        for (p, rows_checked) in [(1usize << 10, 1usize << 10), (1 << 14, 128)] {
            let mut rng = Pcg64::seed(p as u64 ^ 0xB10C);
            let x: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
            let mut got = x.clone();
            fwht_inplace(&mut got);
            let scale = 1.0 / (p as f64).sqrt();
            let stride = p / rows_checked;
            for r in 0..rows_checked {
                let i = r * stride + (r % stride.max(1));
                let want: f64 =
                    (0..p).map(|j| hadamard_sign(i, j) * x[j]).sum::<f64>() * scale;
                assert!(
                    (got[i] - want).abs() < 1e-8,
                    "p={p} row {i}: got {} want {want}",
                    got[i]
                );
            }
        }
    }

    #[test]
    fn bitwise_matches_textbook_reference() {
        // blocking and stage fusion only reorder butterflies on disjoint
        // data — outputs must be identical to the last bit, both below
        // and above FWHT_BLOCK
        for p in [8usize, 16, 64, 256, 512, 1024, 2048, 4096, 1 << 14] {
            let mut rng = Pcg64::seed(p as u64 ^ 0xFACE);
            let x: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
            let mut blocked = x.clone();
            fwht_inplace(&mut blocked);
            let mut textbook = x;
            fwht_textbook(&mut textbook);
            for (i, (a, b)) in blocked.iter().zip(&textbook).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "p={p} index {i}: blocked {a:e} != textbook {b:e}"
                );
            }
        }
    }

    #[test]
    fn scalar_tier_bitwise_matches_textbook_reference() {
        // the scalar fallback must stay byte-identical to the pre-SIMD
        // kernels regardless of what the host CPU supports
        for p in [8usize, 64, 512, 1024, 4096] {
            let mut rng = Pcg64::seed(p as u64 ^ 0x5CA1);
            let x: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
            let mut scalar = x.clone();
            fwht_inplace_isa(&mut scalar, crate::simd::Isa::Scalar);
            let mut textbook = x;
            fwht_textbook(&mut textbook);
            for (a, b) in scalar.iter().zip(&textbook) {
                assert_eq!(a.to_bits(), b.to_bits(), "p={p}");
            }
        }
    }

    #[test]
    fn simd_tiers_bitwise_match_scalar() {
        use crate::simd::{detect, Isa};
        // every available tier must produce bit-identical output to the
        // scalar schedule, across the single-tile, intra-block, and
        // cross-block regimes (odd/even stage counts included)
        for p in [16usize, 32, 64, 128, 256, 512, 1024, 2048, 4096, 8192, 1 << 14] {
            let mut rng = Pcg64::seed(p as u64 ^ 0x51D0);
            let x: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
            let mut want = x.clone();
            fwht_inplace_isa(&mut want, Isa::Scalar);
            for isa in [Isa::Sse2, Isa::Avx2] {
                if detect() < isa {
                    continue;
                }
                let mut got = x.clone();
                fwht_inplace_isa(&mut got, isa);
                for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "isa={} p={p} index {i}: {a:e} != {b:e}",
                        isa.name()
                    );
                }
            }
        }
    }

    #[test]
    fn involutive() {
        for p in [512usize, 4096] {
            let mut rng = Pcg64::seed(2);
            let x: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
            let mut y = x.clone();
            fwht_inplace(&mut y);
            fwht_inplace(&mut y);
            for (a, b) in x.iter().zip(&y) {
                assert!((a - b).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn preserves_norm() {
        let mut rng = Pcg64::seed(3);
        let x: Vec<f64> = (0..1024).map(|_| rng.normal()).collect();
        let n0: f64 = x.iter().map(|v| v * v).sum();
        let mut y = x;
        fwht_inplace(&mut y);
        let n1: f64 = y.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-8 * n0);
    }

    #[test]
    fn trivial_length_one() {
        let mut x = [3.5];
        fwht_inplace(&mut x);
        assert_eq!(x[0], 3.5);
    }
}
