//! In-place fast Walsh–Hadamard transform, normalized (orthonormal), in
//! Sylvester ordering — bit-for-bit the same transform as the Pallas
//! kernel `python/compile/kernels/fwht.py` and the `ref.fwht_ref` oracle.

/// `true` iff `n` is a positive power of two.
#[inline]
pub fn is_pow2(n: usize) -> bool {
    n > 0 && n & (n - 1) == 0
}

/// Normalized in-place FWHT over `x` (length must be a power of two).
/// Involutive: applying twice restores the input. O(p log p).
///
/// Perf (§Perf log): the first two stages (h=1, h=2) are fused into one
/// pass over radix-4 blocks (halves the memory sweeps of the small-stride
/// stages), and the `1/sqrt(p)` normalization is folded into the final
/// stage instead of a separate pass.
pub fn fwht_inplace(x: &mut [f64]) {
    let p = x.len();
    debug_assert!(is_pow2(p), "fwht requires power-of-two length");
    let scale = 1.0 / (p as f64).sqrt();
    if p == 1 {
        x[0] *= scale;
        return;
    }
    if p == 2 {
        let (a, b) = (x[0], x[1]);
        x[0] = (a + b) * scale;
        x[1] = (a - b) * scale;
        return;
    }
    // fused radix-4 first pass (stages h=1 and h=2)
    let mut i = 0;
    while i < p {
        let (a, b, c, d) = (x[i], x[i + 1], x[i + 2], x[i + 3]);
        let (ab, amb) = (a + b, a - b);
        let (cd, cmd) = (c + d, c - d);
        x[i] = ab + cd;
        x[i + 1] = amb + cmd;
        x[i + 2] = ab - cd;
        x[i + 3] = amb - cmd;
        i += 4;
    }
    // remaining stages; fold the normalization into the last one
    let mut h = 4;
    while h < p {
        let step = 2 * h;
        let last = step == p;
        let s = if last { scale } else { 1.0 };
        let mut base = 0;
        while base < p {
            for i in base..base + h {
                let a = x[i];
                let b = x[i + h];
                x[i] = (a + b) * s;
                x[i + h] = (a - b) * s;
            }
            base += step;
        }
        h = step;
    }
    if h == 4 && p == 4 {
        // p == 4: radix-4 pass was the whole transform; normalize now
        for v in x.iter_mut() {
            *v *= scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    /// Explicit orthonormal Hadamard matrix (test oracle).
    fn hadamard(p: usize) -> Vec<Vec<f64>> {
        let mut h = vec![vec![1.0]];
        while h.len() < p {
            let n = h.len();
            let mut next = vec![vec![0.0; 2 * n]; 2 * n];
            for i in 0..n {
                for j in 0..n {
                    next[i][j] = h[i][j];
                    next[i][j + n] = h[i][j];
                    next[i + n][j] = h[i][j];
                    next[i + n][j + n] = -h[i][j];
                }
            }
            h = next;
        }
        let s = 1.0 / (p as f64).sqrt();
        for row in &mut h {
            for v in row {
                *v *= s;
            }
        }
        h
    }

    #[test]
    fn matches_explicit_matrix() {
        for p in [2usize, 4, 8, 32, 128] {
            let mut rng = Pcg64::seed(p as u64);
            let x: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
            let h = hadamard(p);
            let want: Vec<f64> =
                (0..p).map(|i| (0..p).map(|j| h[i][j] * x[j]).sum()).collect();
            let mut got = x.clone();
            fwht_inplace(&mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-10, "p={p}");
            }
        }
    }

    #[test]
    fn involutive() {
        let mut rng = Pcg64::seed(2);
        let x: Vec<f64> = (0..512).map(|_| rng.normal()).collect();
        let mut y = x.clone();
        fwht_inplace(&mut y);
        fwht_inplace(&mut y);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn preserves_norm() {
        let mut rng = Pcg64::seed(3);
        let x: Vec<f64> = (0..1024).map(|_| rng.normal()).collect();
        let n0: f64 = x.iter().map(|v| v * v).sum();
        let mut y = x;
        fwht_inplace(&mut y);
        let n1: f64 = y.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-8 * n0);
    }

    #[test]
    fn trivial_length_one() {
        let mut x = [3.5];
        fwht_inplace(&mut x);
        assert_eq!(x[0], 3.5);
    }
}
