//! Orthonormal DCT-II (the paper's alternative `H`, η = 1/2).
//!
//! Reference implementation via a precomputed p×p matrix: exact for any
//! `p`, O(p²) per column. The streaming hot path prefers the O(p log p)
//! Hadamard transform — when `p` is not a power of two,
//! [`Sparsifier::new`](crate::sampling::Sparsifier::new) transparently
//! zero-pads to the next power of two and samples in the padded space
//! (the adjoint un-pads). The DCT path exists for parity with the
//! paper's MNIST setup and for the η-ablation, mirroring the paper's own
//! remark (§VII.C) that its Matlab DCT was the slow component.

/// Precomputed orthonormal DCT-II plan for dimension `p`.
#[derive(Clone)]
pub struct DctPlan {
    p: usize,
    /// Column-major p×p orthonormal DCT matrix `C`.
    mat: Vec<f64>,
}

impl DctPlan {
    /// Precompute twiddle tables for dimension `p`.
    pub fn new(p: usize) -> Self {
        assert!(p > 0);
        let mut mat = vec![0.0; p * p];
        let norm0 = (1.0 / p as f64).sqrt();
        let norm = (2.0 / p as f64).sqrt();
        for k in 0..p {
            // column k of C (input index k)
            for j in 0..p {
                let c = if j == 0 { norm0 } else { norm };
                mat[k * p + j] =
                    c * (std::f64::consts::PI * (2.0 * k as f64 + 1.0) * j as f64 / (2.0 * p as f64)).cos();
            }
        }
        DctPlan { p, mat }
    }

    /// Dimension the plan was built for.
    pub fn p(&self) -> usize {
        self.p
    }

    /// `y = C x`, written back into `x` (`scratch` must have length `p`).
    pub fn forward(&self, x: &mut [f64], scratch: &mut [f64]) {
        let p = self.p;
        debug_assert_eq!(x.len(), p);
        debug_assert_eq!(scratch.len(), p);
        scratch.fill(0.0);
        for (k, &xk) in x.iter().enumerate() {
            if xk == 0.0 {
                continue;
            }
            let col = &self.mat[k * p..(k + 1) * p];
            for j in 0..p {
                scratch[j] += col[j] * xk;
            }
        }
        x.copy_from_slice(scratch);
    }

    /// `x = Cᵀ y` (exact inverse of [`forward`](Self::forward)), in place.
    pub fn inverse(&self, y: &mut [f64], scratch: &mut [f64]) {
        let p = self.p;
        debug_assert_eq!(y.len(), p);
        for k in 0..p {
            let col = &self.mat[k * p..(k + 1) * p];
            let mut s = 0.0;
            for j in 0..p {
                s += col[j] * y[j];
            }
            scratch[k] = s;
        }
        y.copy_from_slice(scratch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn orthonormal_any_p() {
        for p in [3usize, 8, 17, 100] {
            let plan = DctPlan::new(p);
            // C Cᵀ = I  (check a few random columns of the product)
            for i in 0..p {
                for j in 0..p {
                    let mut s = 0.0;
                    for k in 0..p {
                        s += plan.mat[i * p + k] * plan.mat[j * p + k];
                    }
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((s - want).abs() < 1e-10, "p={p} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn forward_inverse_roundtrip() {
        let p = 97;
        let plan = DctPlan::new(p);
        let mut rng = Pcg64::seed(4);
        let x: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
        let mut y = x.clone();
        let mut scratch = vec![0.0; p];
        plan.forward(&mut y, &mut scratch);
        plan.inverse(&mut y, &mut scratch);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn constant_maps_to_first_coefficient() {
        let p = 64;
        let plan = DctPlan::new(p);
        let mut x = vec![1.0; p];
        let mut scratch = vec![0.0; p];
        plan.forward(&mut x, &mut scratch);
        assert!((x[0] - (p as f64).sqrt()).abs() < 1e-10);
        for v in &x[1..] {
            assert!(v.abs() < 1e-10);
        }
    }
}
