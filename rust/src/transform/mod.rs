//! The randomized orthonormal system (ROS) preconditioner — paper Eq. (1):
//! `y = H D x` with `H` a fast orthonormal transform (Hadamard or DCT-II)
//! and `D` a random ±1 diagonal.
//!
//! This is the L3-native implementation used on the streaming hot path;
//! the identical computation is also AOT-compiled from the Pallas FWHT
//! kernel (`python/compile/kernels/fwht.py`) and the two are
//! cross-checked in `rust/tests/xla_parity.rs`.

mod dct;
pub(crate) mod fwht;

pub use dct::DctPlan;
pub use fwht::{fwht_inplace, is_pow2};

use crate::error::{invalid, Result};
use crate::linalg::Mat;
use crate::rng::{signs, Pcg64};

/// Which orthonormal `H` the ROS uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransformKind {
    /// Walsh–Hadamard (requires `p` a power of two); `η = 1` in Thm 1.
    Hadamard,
    /// Orthonormal DCT-II (any `p`); `η = 1/2` in Thm 1.
    Dct,
}

impl TransformKind {
    /// The sub-Gaussian constant `η` of Theorem 1 for this transform.
    pub fn eta(self) -> f64 {
        match self {
            TransformKind::Hadamard => 1.0,
            TransformKind::Dct => 0.5,
        }
    }

    /// Stable lowercase name, used by store manifests and CLI flags.
    pub fn name(self) -> &'static str {
        match self {
            TransformKind::Hadamard => "hadamard",
            TransformKind::Dct => "dct",
        }
    }

    /// Inverse of [`name`](Self::name); `None` for unknown strings.
    pub fn from_name(s: &str) -> Option<TransformKind> {
        match s {
            "hadamard" => Some(TransformKind::Hadamard),
            "dct" => Some(TransformKind::Dct),
            _ => None,
        }
    }
}

/// A sampled ROS instance: the `D` diagonal (±1 signs) plus the `H` plan.
///
/// `HD` is orthonormal, so [`Ros::adjoint_inplace`] is an exact inverse of
/// [`Ros::apply_inplace`]; center estimates computed in the preconditioned
/// domain are unmixed with the adjoint (paper Eq. 32).
#[derive(Clone)]
pub struct Ros {
    kind: TransformKind,
    signs: Vec<f64>,
    dct: Option<DctPlan>,
    p: usize,
}

impl Ros {
    /// Sample a ROS for dimension `p`. The sign diagonal is drawn from
    /// `rng`; Hadamard requires `p` to be a power of two.
    pub fn new(p: usize, kind: TransformKind, rng: &mut Pcg64) -> Result<Self> {
        if p == 0 {
            return invalid("Ros: p must be positive");
        }
        if kind == TransformKind::Hadamard && !is_pow2(p) {
            return invalid(format!("Ros: Hadamard needs power-of-two p, got {p}"));
        }
        let dct = match kind {
            TransformKind::Dct => Some(DctPlan::new(p)),
            TransformKind::Hadamard => None,
        };
        Ok(Ros { kind, signs: signs(p, rng), dct, p })
    }

    /// Dimension this ROS instance was sampled for.
    pub fn p(&self) -> usize {
        self.p
    }

    /// Which orthonormal transform `H` this instance applies.
    pub fn kind(&self) -> TransformKind {
        self.kind
    }

    /// The ±1 diagonal of `D`.
    pub fn signs(&self) -> &[f64] {
        &self.signs
    }

    /// `x ← H D x` for one column (scratch required by the DCT path; pass
    /// a reusable buffer of length `p`).
    pub fn apply_col(&self, x: &mut [f64], scratch: &mut [f64]) {
        debug_assert_eq!(x.len(), self.p);
        for (v, s) in x.iter_mut().zip(&self.signs) {
            *v *= s;
        }
        match self.kind {
            TransformKind::Hadamard => fwht_inplace(x),
            TransformKind::Dct => self.dct.as_ref().unwrap().forward(x, scratch),
        }
    }

    /// `y ← (HD)ᵀ y = D Hᵀ y` for one column (exact inverse of
    /// [`apply_col`](Self::apply_col)).
    pub fn adjoint_col(&self, y: &mut [f64], scratch: &mut [f64]) {
        debug_assert_eq!(y.len(), self.p);
        match self.kind {
            TransformKind::Hadamard => fwht_inplace(y), // H is symmetric & involutive
            TransformKind::Dct => self.dct.as_ref().unwrap().inverse(y, scratch),
        }
        for (v, s) in y.iter_mut().zip(&self.signs) {
            *v *= s;
        }
    }

    /// Apply in place to every column of a matrix.
    pub fn apply_inplace(&self, x: &mut Mat) {
        assert_eq!(x.rows(), self.p);
        let mut scratch = vec![0.0; self.p];
        for j in 0..x.cols() {
            self.apply_col(x.col_mut(j), &mut scratch);
        }
    }

    /// Apply the adjoint in place to every column of a matrix.
    pub fn adjoint_inplace(&self, y: &mut Mat) {
        assert_eq!(y.rows(), self.p);
        let mut scratch = vec![0.0; self.p];
        for j in 0..y.cols() {
            self.adjoint_col(y.col_mut(j), &mut scratch);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::forall;

    #[test]
    fn hadamard_roundtrip() {
        forall("ros_hadamard_roundtrip", 20, |g| {
            let p = 1usize << g.int(1, 9);
            let mut rng = Pcg64::seed(g.int(0, 1 << 30) as u64);
            let ros = Ros::new(p, TransformKind::Hadamard, &mut rng).unwrap();
            let mut x: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
            let orig = x.clone();
            let mut scratch = vec![0.0; p];
            ros.apply_col(&mut x, &mut scratch);
            ros.adjoint_col(&mut x, &mut scratch);
            for (a, b) in x.iter().zip(&orig) {
                assert!((a - b).abs() < 1e-9, "roundtrip failed");
            }
        });
    }

    #[test]
    fn dct_roundtrip_arbitrary_p() {
        forall("ros_dct_roundtrip", 20, |g| {
            let p = g.int(2, 300) as usize;
            let mut rng = Pcg64::seed(g.int(0, 1 << 30) as u64);
            let ros = Ros::new(p, TransformKind::Dct, &mut rng).unwrap();
            let mut x: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
            let orig = x.clone();
            let mut scratch = vec![0.0; p];
            ros.apply_col(&mut x, &mut scratch);
            ros.adjoint_col(&mut x, &mut scratch);
            for (a, b) in x.iter().zip(&orig) {
                assert!((a - b).abs() < 1e-9);
            }
        });
    }

    #[test]
    fn preserves_column_norms() {
        let mut rng = Pcg64::seed(9);
        for kind in [TransformKind::Hadamard, TransformKind::Dct] {
            let p = 128;
            let ros = Ros::new(p, kind, &mut rng).unwrap();
            let mut x = Mat::from_fn(p, 5, |_, _| rng.normal());
            let before: Vec<f64> =
                (0..5).map(|j| x.col(j).iter().map(|v| v * v).sum::<f64>()).collect();
            ros.apply_inplace(&mut x);
            for j in 0..5 {
                let after: f64 = x.col(j).iter().map(|v| v * v).sum();
                assert!((after - before[j]).abs() < 1e-8 * before[j].max(1.0));
            }
        }
    }

    #[test]
    fn smooths_spike_to_uniform_magnitude() {
        // Theorem 1: a canonical basis vector maps to entries of magnitude
        // exactly 1/sqrt(p) under Hadamard.
        let p = 256;
        let mut rng = Pcg64::seed(3);
        let ros = Ros::new(p, TransformKind::Hadamard, &mut rng).unwrap();
        let mut x = vec![0.0; p];
        x[37] = 1.0;
        let mut scratch = vec![0.0; p];
        ros.apply_col(&mut x, &mut scratch);
        for v in &x {
            assert!((v.abs() - 1.0 / (p as f64).sqrt()).abs() < 1e-12);
        }
    }

    #[test]
    fn hadamard_rejects_non_pow2() {
        let mut rng = Pcg64::seed(1);
        assert!(Ros::new(100, TransformKind::Hadamard, &mut rng).is_err());
        assert!(Ros::new(100, TransformKind::Dct, &mut rng).is_ok());
    }

    #[test]
    fn max_entry_bound_corollary2() {
        // Corollary 2: for normalized columns, ||Y||_max is unlikely to
        // exceed sqrt(2/eta * log(2np/alpha) / p). Check at alpha=0.01.
        let (p, n) = (256, 64);
        let mut rng = Pcg64::seed(77);
        let ros = Ros::new(p, TransformKind::Hadamard, &mut rng).unwrap();
        let mut x = Mat::from_fn(p, n, |_, _| rng.normal());
        x.normalize_columns();
        ros.apply_inplace(&mut x);
        let alpha = 0.01f64;
        let bound =
            ((2.0 / 1.0) * (2.0 * (n * p) as f64 / alpha).ln()).sqrt() / (p as f64).sqrt();
        assert!(x.max_abs() <= bound, "max {} bound {}", x.max_abs(), bound);
    }
}
