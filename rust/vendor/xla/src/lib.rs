//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate links libpjrt and cannot be fetched or built in this
//! offline container. This stub keeps the exact API surface that
//! `pds::runtime::XlaEngine` compiles against, but every entry point that
//! would touch the PJRT runtime returns [`Error`]; `PjRtClient::cpu()`
//! fails first, so the engine reports itself unavailable at construction
//! and the pure-Rust `NativeEngine` remains the execution path.
//! Restoring real PJRT execution is a matter of swapping this path
//! dependency back to the upstream crate — no `pds` source changes.

use std::path::Path;

/// Error type mirroring `xla::Error`'s role (stringly, `Display`-able).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT runtime unavailable: this build uses the offline `xla` stub \
         (vendor/xla); use the native engine instead"
            .to_string(),
    ))
}

/// Element types extractable from a [`Literal`].
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}

/// Host-side tensor value.
#[derive(Debug, Clone, Default)]
pub struct Literal {
    data_f32: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 f32 literal.
    pub fn vec1(v: &[f32]) -> Literal {
        Literal { data_f32: v.to_vec(), dims: vec![v.len() as i64] }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count != self.data_f32.len() as i64 {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.data_f32.len()
            )));
        }
        Ok(Literal { data_f32: self.data_f32.clone(), dims: dims.to_vec() })
    }

    /// Extract the buffer as a flat vector. Stub literals only ever hold
    /// host-constructed f32 inputs, never device outputs, so this is
    /// unreachable in practice and reports unavailability.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (stub: never constructible from a real file).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-side buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client handle. `cpu()` always fails in the stub, which is the
/// single gate that keeps the rest of this API unreachable at runtime.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        let msg = PjRtClient::cpu().err().unwrap().to_string();
        assert!(msg.contains("stub"), "{msg}");
    }

    #[test]
    fn literal_roundtrip_shapes() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.dims(), &[6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert!(l.reshape(&[4, 4]).is_err());
    }
}
