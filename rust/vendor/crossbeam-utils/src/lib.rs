//! Offline stand-in for the `crossbeam-utils` crate.
//!
//! This container builds with no network access, so the real
//! `crossbeam-utils` cannot be fetched from crates.io. The workspace only
//! uses `crossbeam_utils::thread::scope`, which since Rust 1.63 is
//! expressible directly over [`std::thread::scope`]; this crate provides
//! that one API with crossbeam's error-reporting convention (a panicking
//! child thread surfaces as an `Err` from `scope` instead of a panic on
//! the caller's thread).
//!
//! Deliberate divergence from the real crate: the closure passed to
//! [`thread::Scope::spawn`] receives a `&()` placeholder instead of a
//! nested `&Scope` (no spawning from inside a spawned thread). Every call
//! site in this repository ignores the argument (`|_| ...`), and keeping
//! the placeholder avoids exposing std's second scope lifetime through
//! the shim.

pub mod thread {
    use std::panic::{catch_unwind, AssertUnwindSafe};

    /// Fork/join scope handed to the `scope` closure. Wraps
    /// [`std::thread::Scope`].
    pub struct Scope<'scope, 'env: 'scope> {
        inner: &'scope std::thread::Scope<'scope, 'env>,
    }

    /// Join handle for a thread spawned in a [`Scope`].
    pub struct ScopedJoinHandle<'scope, T> {
        inner: std::thread::ScopedJoinHandle<'scope, T>,
    }

    impl<'scope, T> ScopedJoinHandle<'scope, T> {
        /// Wait for the thread to finish; `Err` carries its panic payload.
        pub fn join(self) -> std::thread::Result<T> {
            self.inner.join()
        }
    }

    impl<'scope, 'env> Scope<'scope, 'env> {
        /// Spawn a scoped thread. The closure's `&()` argument stands in
        /// for crossbeam's nested `&Scope` (see crate docs).
        pub fn spawn<F, T>(&self, f: F) -> ScopedJoinHandle<'scope, T>
        where
            F: FnOnce(&()) -> T + Send + 'scope,
            T: Send + 'scope,
        {
            ScopedJoinHandle { inner: self.inner.spawn(move || f(&())) }
        }
    }

    /// Create a fork/join scope; all spawned threads are joined before
    /// this returns. A panic in an unjoined child (or in the closure
    /// itself) is captured and returned as `Err`.
    pub fn scope<'env, F, R>(f: F) -> std::thread::Result<R>
    where
        F: for<'scope> FnOnce(&Scope<'scope, 'env>) -> R,
    {
        catch_unwind(AssertUnwindSafe(|| {
            std::thread::scope(|s| f(&Scope { inner: s }))
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::thread;

    #[test]
    fn scope_joins_and_returns() {
        let data = vec![1u64, 2, 3, 4];
        let total = thread::scope(|s| {
            let (a, b) = data.split_at(2);
            let ha = s.spawn(|_| a.iter().sum::<u64>());
            let hb = s.spawn(|_| b.iter().sum::<u64>());
            ha.join().unwrap() + hb.join().unwrap()
        })
        .unwrap();
        assert_eq!(total, 10);
    }

    #[test]
    fn child_panic_is_err_not_abort() {
        let r = thread::scope(|s| {
            let h = s.spawn(|_| -> u32 { panic!("boom") });
            h.join().is_err()
        });
        // the panic was already consumed via join(); scope itself is Ok
        assert_eq!(r.unwrap(), true);
    }

    #[test]
    fn unjoined_child_panic_surfaces_as_scope_err() {
        let r: std::thread::Result<()> = thread::scope(|s| {
            s.spawn(|_| panic!("unjoined"));
        });
        assert!(r.is_err());
    }
}
