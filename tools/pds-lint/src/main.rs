//! CLI driver: `cargo run -p pds-lint [-- --root DIR] [--write-baseline] [--deny-stale]`

use std::path::PathBuf;
use std::process::ExitCode;

use pds_lint::{find_root, parse_baseline, render_baseline, run, Baseline, BASELINE_FILE};

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut write_baseline = false;
    let mut deny_stale = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => root = args.next().map(PathBuf::from),
            "--write-baseline" => write_baseline = true,
            "--deny-stale" => deny_stale = true,
            "--help" | "-h" => {
                println!(
                    "pds-lint: repo-local static analysis for the pds crate\n\n\
                     USAGE: pds-lint [--root DIR] [--write-baseline] [--deny-stale]\n\n\
                     Checks safety-contract, lossy-cast, unwrap, atomic-ordering and\n\
                     deprecated-name rules against {BASELINE_FILE} at the repo root.\n\
                     --write-baseline  regenerate the baseline from the current tree\n\
                     --deny-stale      also fail when a baseline entry exceeds reality\n\
                     \x20                 (CI: the debt may only shrink)"
                );
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("pds-lint: unknown argument `{other}` (try --help)");
                return ExitCode::FAILURE;
            }
        }
    }

    let cwd = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    let Some(root) = root.or_else(|| find_root(&cwd)) else {
        eprintln!("pds-lint: could not find the repo root (a directory containing rust/src)");
        return ExitCode::FAILURE;
    };

    let baseline_path = root.join(BASELINE_FILE);
    let baseline: Baseline = match std::fs::read_to_string(&baseline_path) {
        Ok(text) => parse_baseline(&text),
        Err(_) => Baseline::new(),
    };

    let report = run(&root, &baseline);

    if write_baseline {
        let text = render_baseline(&report.actual);
        if let Err(e) = std::fs::write(&baseline_path, text) {
            eprintln!("pds-lint: cannot write {}: {e}", baseline_path.display());
            return ExitCode::FAILURE;
        }
        let total: usize = report.actual.values().sum();
        println!(
            "pds-lint: wrote {} ({} grandfathered violations across {} (rule, file) pairs)",
            baseline_path.display(),
            total,
            report.actual.len()
        );
        return ExitCode::SUCCESS;
    }

    for v in &report.violations {
        println!("{}", v.render());
    }
    let mut failed = !report.violations.is_empty();
    if deny_stale {
        for s in &report.stale {
            println!("error[stale-baseline]: {s}");
        }
        failed = failed || !report.stale.is_empty();
    }
    println!(
        "pds-lint: {} file(s) scanned, {} violation(s), {} baselined{}",
        report.files_scanned,
        report.violations.len(),
        report.baselined,
        if deny_stale {
            format!(", {} stale baseline entr(ies)", report.stale.len())
        } else {
            String::new()
        }
    );
    if failed {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
