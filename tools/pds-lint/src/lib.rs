//! pds-lint — repo-local static analysis for the `pds` crate.
//!
//! A dependency-free linter over a hand-rolled Rust token stream. It
//! does not parse Rust; it lexes it (comments, strings, and char
//! literals stripped from the token stream but comment *content*
//! retained per line) and checks token-pattern rules that `rustc` and
//! `clippy` do not enforce:
//!
//! * **safety-contract** — every `unsafe fn` carries a `# Safety` doc
//!   section (or `// SAFETY:` comment) and every `unsafe { .. }` block
//!   a `// SAFETY:` comment on or immediately above it.
//! * **lossy-cast** — no `as <numeric-type>` casts in library code;
//!   audited sites opt out with a `lint:allow(lossy-cast)` comment,
//!   everything else goes through `pds::convert` or is baselined.
//! * **unwrap** — no `.unwrap()` / `.expect(..)` in non-test library
//!   code; library errors are typed `pds::Error` values.
//! * **atomic-ordering** — every atomic `Ordering::X` in the `serve`
//!   daemon names its ordering in a same-line or immediately-above
//!   comment justifying the choice.
//! * **deprecated-name** — the pre-`FitPlan` `run_*` entry points may
//!   be referenced only from their compatibility shims in
//!   `coordinator/{driver,krylov,mod}.rs`.
//!
//! Violations are reported rustc-style (`path:line:col`). Pre-existing
//! debt lives in `pds-lint.baseline` at the repo root as per-file
//! per-rule *counts*: a file may never exceed its baselined count, and
//! in CI (`--deny-stale`) the counts may only shrink — fixing a site
//! requires re-running with `--write-baseline` so the debt burns down
//! monotonically.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

/// Directories scanned, relative to the repo root. `rust/vendor` and
/// `tools/` are deliberately absent: vendored shims and the linter
/// itself are not the crate's library surface.
pub const SCAN_DIRS: &[&str] = &["rust/src", "rust/tests", "rust/benches", "examples"];

/// Name of the committed baseline file at the repo root.
pub const BASELINE_FILE: &str = "pds-lint.baseline";

const NUMERIC_TYPES: &[&str] = &[
    "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize",
    "f32", "f64",
];

const ATOMIC_ORDERINGS: &[&str] = &["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

/// The pre-`FitPlan` entry points retired in the coordinator redesign.
pub const DEPRECATED_NAMES: &[&str] = &[
    "run_pca_stream",
    "run_pca_sparse",
    "run_pca_from_store",
    "run_pca_krylov_stream",
    "run_pca_krylov_sparse",
    "run_pca_krylov_from_store",
    "run_sparsified_kmeans_stream",
    "run_sparsified_kmeans_sparse",
    "run_sparsified_kmeans_from_store",
    "run_two_pass_stream",
    "run_compress_to_store",
];

/// Files allowed to mention the deprecated names: the deprecation shims
/// themselves and the module that re-exports them.
const DEPRECATED_ALLOW: &[&str] = &[
    "rust/src/coordinator/driver.rs",
    "rust/src/coordinator/krylov.rs",
    "rust/src/coordinator/mod.rs",
];

/// One token of stripped Rust source.
#[derive(Debug, Clone)]
pub struct Tok {
    pub text: String,
    pub line: usize,
    pub col: usize,
}

/// Lexed view of one file: the code token stream plus per-line comment
/// content (rules check comments for contracts and justifications).
pub struct Lexed {
    pub tokens: Vec<Tok>,
    /// Concatenated comment text per line (1-indexed via `line - 1`).
    pub comment_text: Vec<String>,
    /// Line holds comments and whitespace only (no code tokens).
    pub comment_only: Vec<bool>,
    /// Raw source lines (for blank / attribute detection).
    pub raw_lines: Vec<String>,
}

/// A single finding.
#[derive(Debug, Clone)]
pub struct Violation {
    pub rule: &'static str,
    /// Repo-relative path with forward slashes.
    pub path: String,
    pub line: usize,
    pub col: usize,
    pub msg: String,
}

impl Violation {
    /// `path:line:col: error[rule]: msg`
    pub fn render(&self) -> String {
        format!(
            "{}:{}:{}: error[{}]: {}",
            self.path, self.line, self.col, self.rule, self.msg
        )
    }
}

/// Lex `src` into tokens + per-line comment info.
///
/// The lexer strips line/block comments (content retained per line),
/// string/char literals, lifetimes, and raw strings; identifiers,
/// numbers, `::`, and single punctuation chars become tokens.
pub fn lex(src: &str) -> Lexed {
    let bytes: Vec<char> = src.chars().collect();
    let n_lines = src.lines().count().max(1);
    let mut tokens = Vec::new();
    let mut comment_text = vec![String::new(); n_lines + 1];
    let mut has_comment = vec![false; n_lines + 1];
    let mut has_code = vec![false; n_lines + 1];
    let raw_lines: Vec<String> = src.lines().map(str::to_string).collect();

    let mut i = 0usize;
    let mut line = 1usize;
    let mut col = 1usize;
    let n = bytes.len();
    let at = |i: usize| -> char {
        if i < n {
            bytes[i]
        } else {
            '\0'
        }
    };

    while i < n {
        let c = bytes[i];
        if c == '\n' {
            line += 1;
            col = 1;
            i += 1;
            continue;
        }
        if c == '/' && at(i + 1) == '/' {
            let start = i;
            while i < n && bytes[i] != '\n' {
                i += 1;
            }
            let text: String = bytes[start..i].iter().collect();
            if line <= n_lines {
                comment_text[line - 1].push_str(&text);
                comment_text[line - 1].push(' ');
                has_comment[line - 1] = true;
            }
            continue; // newline handled at loop top
        }
        if c == '/' && at(i + 1) == '*' {
            // nested block comment; attribute content to every line it spans
            let mut depth = 1usize;
            i += 2;
            col += 2;
            let mut seg = String::from("/*");
            while i < n && depth > 0 {
                if bytes[i] == '\n' {
                    if line <= n_lines {
                        comment_text[line - 1].push_str(&seg);
                        comment_text[line - 1].push(' ');
                        has_comment[line - 1] = true;
                    }
                    seg.clear();
                    line += 1;
                    col = 1;
                    i += 1;
                    continue;
                }
                if bytes[i] == '/' && at(i + 1) == '*' {
                    depth += 1;
                    seg.push_str("/*");
                    i += 2;
                    col += 2;
                    continue;
                }
                if bytes[i] == '*' && at(i + 1) == '/' {
                    depth -= 1;
                    seg.push_str("*/");
                    i += 2;
                    col += 2;
                    continue;
                }
                seg.push(bytes[i]);
                i += 1;
                col += 1;
            }
            if !seg.is_empty() && line <= n_lines {
                comment_text[line - 1].push_str(&seg);
                comment_text[line - 1].push(' ');
                has_comment[line - 1] = true;
            }
            continue;
        }
        // raw strings / byte strings: r"..", r#".."#, br".., b".."
        if (c == 'r' || c == 'b') && (at(i + 1) == '"' || at(i + 1) == '#' || (c == 'b' && at(i + 1) == 'r')) {
            let mut j = i + 1;
            let mut raw = c == 'r';
            if c == 'b' && at(j) == 'r' {
                raw = true;
                j += 1;
            }
            let mut hashes = 0usize;
            while at(j) == '#' {
                hashes += 1;
                j += 1;
            }
            if at(j) == '"' && (raw || hashes == 0) {
                // consume the literal
                if line <= n_lines {
                    has_code[line - 1] = true;
                }
                j += 1;
                loop {
                    if j >= n {
                        break;
                    }
                    let d = bytes[j];
                    if d == '\n' {
                        line += 1;
                        col = 1;
                        j += 1;
                        if line <= n_lines {
                            has_code[line - 1] = true;
                        }
                        continue;
                    }
                    if !raw && d == '\\' {
                        j += 2;
                        col += 2;
                        continue;
                    }
                    if d == '"' {
                        let mut k = j + 1;
                        let mut close = 0usize;
                        while close < hashes && at(k) == '#' {
                            close += 1;
                            k += 1;
                        }
                        if close == hashes {
                            j = k;
                            col += 1 + hashes;
                            break;
                        }
                    }
                    j += 1;
                    col += 1;
                }
                i = j;
                continue;
            }
            // not a string start: fall through to identifier lexing
        }
        if c == '"' {
            if line <= n_lines {
                has_code[line - 1] = true;
            }
            i += 1;
            col += 1;
            while i < n {
                let d = bytes[i];
                if d == '\\' {
                    i += 2;
                    col += 2;
                    continue;
                }
                if d == '\n' {
                    line += 1;
                    col = 1;
                    i += 1;
                    if line <= n_lines {
                        has_code[line - 1] = true;
                    }
                    continue;
                }
                i += 1;
                col += 1;
                if d == '"' {
                    break;
                }
            }
            continue;
        }
        if c == '\'' {
            // lifetime ('a, 'static) vs char literal ('x', '\n', '\u{41}')
            let c1 = at(i + 1);
            if (c1.is_alphabetic() || c1 == '_') && at(i + 2) != '\'' {
                i += 1;
                col += 1;
                while i < n && (bytes[i].is_alphanumeric() || bytes[i] == '_') {
                    i += 1;
                    col += 1;
                }
                continue;
            }
            if line <= n_lines {
                has_code[line - 1] = true;
            }
            i += 1;
            col += 1;
            while i < n {
                let d = bytes[i];
                if d == '\\' {
                    i += 2;
                    col += 2;
                    continue;
                }
                i += 1;
                col += 1;
                if d == '\'' || d == '\n' {
                    if d == '\n' {
                        line += 1;
                        col = 1;
                    }
                    break;
                }
            }
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let start = i;
            let start_col = col;
            while i < n && (bytes[i].is_alphanumeric() || bytes[i] == '_') {
                i += 1;
                col += 1;
            }
            let text: String = bytes[start..i].iter().collect();
            if line <= n_lines {
                has_code[line - 1] = true;
            }
            tokens.push(Tok { text, line, col: start_col });
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            let start_col = col;
            while i < n && (bytes[i].is_alphanumeric() || bytes[i] == '_') {
                i += 1;
                col += 1;
            }
            // fractional part: `1.5` but not `1..3` or `1.method()`
            if at(i) == '.' && at(i + 1).is_ascii_digit() {
                i += 1;
                col += 1;
                while i < n && (bytes[i].is_alphanumeric() || bytes[i] == '_') {
                    i += 1;
                    col += 1;
                }
            }
            let text: String = bytes[start..i].iter().collect();
            if line <= n_lines {
                has_code[line - 1] = true;
            }
            tokens.push(Tok { text, line, col: start_col });
            continue;
        }
        if c == ':' && at(i + 1) == ':' {
            if line <= n_lines {
                has_code[line - 1] = true;
            }
            tokens.push(Tok { text: "::".to_string(), line, col });
            i += 2;
            col += 2;
            continue;
        }
        if !c.is_whitespace() {
            if line <= n_lines {
                has_code[line - 1] = true;
            }
            tokens.push(Tok { text: c.to_string(), line, col });
        }
        i += 1;
        col += 1;
    }

    let comment_only: Vec<bool> = (0..n_lines)
        .map(|l| has_comment[l] && !has_code[l])
        .collect();
    Lexed {
        tokens,
        comment_text: comment_text.into_iter().take(n_lines).collect(),
        comment_only,
        raw_lines,
    }
}

/// Token-index ranges covered by `#[cfg(test)]` / `#[test]` items
/// (attribute through the end of the annotated item).
pub fn test_ranges(tokens: &[Tok]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let mut i = 0usize;
    let n = tokens.len();
    while i < n {
        if tokens[i].text == "#" && i + 1 < n && tokens[i + 1].text == "[" {
            // matching `]` of the attribute
            let mut depth = 0usize;
            let mut j = i + 1;
            while j < n {
                match tokens[j].text.as_str() {
                    "[" => depth += 1,
                    "]" => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            if j >= n {
                break;
            }
            let inner: Vec<&str> = tokens[i + 2..j].iter().map(|t| t.text.as_str()).collect();
            let is_test_attr = (inner.first() == Some(&"cfg") && inner.contains(&"test"))
                || inner == ["test"];
            if is_test_attr {
                // skip any further attributes on the same item
                let mut k = j + 1;
                while k + 1 < n && tokens[k].text == "#" && tokens[k + 1].text == "[" {
                    let mut d = 0usize;
                    while k < n {
                        match tokens[k].text.as_str() {
                            "[" => d += 1,
                            "]" => {
                                d -= 1;
                                if d == 0 {
                                    break;
                                }
                            }
                            _ => {}
                        }
                        k += 1;
                    }
                    k += 1;
                }
                // item extent: first `;` at depth 0 ends it, or the
                // matching `}` of the first `{` at depth 0
                let mut d = 0isize;
                let mut end = n.saturating_sub(1);
                while k < n {
                    match tokens[k].text.as_str() {
                        "{" if d == 0 => {
                            let mut b = 0isize;
                            while k < n {
                                match tokens[k].text.as_str() {
                                    "{" => b += 1,
                                    "}" => {
                                        b -= 1;
                                        if b == 0 {
                                            break;
                                        }
                                    }
                                    _ => {}
                                }
                                k += 1;
                            }
                            end = k.min(n - 1);
                            break;
                        }
                        "(" | "[" | "{" => d += 1,
                        ")" | "]" | "}" => d -= 1,
                        ";" if d == 0 => {
                            end = k;
                            break;
                        }
                        _ => {}
                    }
                    k += 1;
                }
                if k >= n {
                    end = n - 1;
                }
                ranges.push((i, end));
                i = end + 1;
                continue;
            }
        }
        i += 1;
    }
    ranges
}

fn in_ranges(ranges: &[(usize, usize)], idx: usize) -> bool {
    ranges.iter().any(|&(a, b)| idx >= a && idx <= b)
}

/// Concatenated comment text of the contiguous comment-only run ending
/// at `line - 1` (1-indexed `line`).
fn comment_run_above(lx: &Lexed, line: usize) -> String {
    let mut acc = String::new();
    let mut l = line;
    while l >= 2 && *lx.comment_only.get(l - 2).unwrap_or(&false) {
        acc.push_str(&lx.comment_text[l - 2]);
        acc.push(' ');
        l -= 1;
    }
    acc
}

/// Like [`comment_run_above`] but first skips blank lines and
/// single-line attributes (`#[..]`) — the shape of a doc comment above
/// an attributed `unsafe fn`.
fn doc_run_above(lx: &Lexed, line: usize) -> String {
    let mut l = line; // 1-indexed; examine l-1 next
    while l >= 2 {
        let raw = lx.raw_lines.get(l - 2).map(String::as_str).unwrap_or("");
        let t = raw.trim_start();
        if t.is_empty() || t.starts_with("#[") || t.starts_with("#![") {
            l -= 1;
            continue;
        }
        break;
    }
    comment_run_above(lx, l)
}

/// Run every applicable rule over one file. `path` is repo-relative
/// with forward slashes; it selects which rules apply.
pub fn lint_file(path: &str, src: &str) -> Vec<Violation> {
    let lx = lex(src);
    let tests = test_ranges(&lx.tokens);
    let mut out = Vec::new();
    let toks = &lx.tokens;
    let n = toks.len();

    let in_lib = path.starts_with("rust/src/");
    let in_serve = path.starts_with("rust/src/serve/");
    let dep_allowed = DEPRECATED_ALLOW.contains(&path);

    for i in 0..n {
        let t = &toks[i];
        let text = t.text.as_str();
        let next = |k: usize| toks.get(i + k).map(|t| t.text.as_str()).unwrap_or("");

        // --- safety-contract ---
        if text == "unsafe" && !in_ranges(&tests, i) {
            let is_fn = next(1) == "fn" || (next(1) == "extern" && next(2) == "fn");
            let is_block = next(1) == "{";
            if is_fn {
                let doc = doc_run_above(&lx, t.line);
                if !doc.contains("SAFETY") && !doc.contains("# Safety") {
                    out.push(Violation {
                        rule: "safety-contract",
                        path: path.to_string(),
                        line: t.line,
                        col: t.col,
                        msg: "unsafe fn without a `# Safety` doc section (or `// SAFETY:` \
                              comment) stating its preconditions"
                            .to_string(),
                    });
                }
            } else if is_block {
                let same_line = &lx.comment_text[t.line - 1];
                let above = comment_run_above(&lx, t.line);
                if !same_line.contains("SAFETY") && !above.contains("SAFETY") {
                    out.push(Violation {
                        rule: "safety-contract",
                        path: path.to_string(),
                        line: t.line,
                        col: t.col,
                        msg: "unsafe block without a `// SAFETY:` comment on or immediately \
                              above it"
                            .to_string(),
                    });
                }
            }
            // `unsafe impl` / `unsafe trait` carry their contract on the
            // trait definition; not flagged here.
        }

        // --- lossy-cast ---
        if in_lib
            && text == "as"
            && NUMERIC_TYPES.contains(&next(1))
            && !in_ranges(&tests, i)
        {
            let same_line = &lx.comment_text[t.line - 1];
            let above = comment_run_above(&lx, t.line);
            let marker = "lint:allow(lossy-cast)";
            if !same_line.contains(marker) && !above.contains(marker) {
                out.push(Violation {
                    rule: "lossy-cast",
                    path: path.to_string(),
                    line: t.line,
                    col: t.col,
                    msg: format!(
                        "`as {}` cast in library code; use a `pds::convert` checked helper \
                         or mark the audited site with `lint:allow(lossy-cast)`",
                        next(1)
                    ),
                });
            }
        }

        // --- unwrap ---
        if in_lib && text == "." && !in_ranges(&tests, i) {
            let is_unwrap = next(1) == "unwrap" && next(2) == "(" && next(3) == ")";
            let is_expect = next(1) == "expect" && next(2) == "(";
            if is_unwrap || is_expect {
                out.push(Violation {
                    rule: "unwrap",
                    path: path.to_string(),
                    line: toks[i + 1].line,
                    col: toks[i + 1].col,
                    msg: format!(
                        "`.{}(..)` in non-test library code; return a typed `pds::Error` \
                         instead",
                        next(1)
                    ),
                });
            }
        }

        // --- atomic-ordering ---
        if in_serve
            && text == "Ordering"
            && next(1) == "::"
            && ATOMIC_ORDERINGS.contains(&next(2))
            && !in_ranges(&tests, i)
        {
            let ord = next(2);
            let same_line = &lx.comment_text[t.line - 1];
            let above = comment_run_above(&lx, t.line);
            if !same_line.contains(ord) && !above.contains(ord) {
                out.push(Violation {
                    rule: "atomic-ordering",
                    path: path.to_string(),
                    line: t.line,
                    col: t.col,
                    msg: format!(
                        "atomic access uses `Ordering::{ord}` without a comment naming \
                         `{ord}` and justifying it (same line or immediately above)"
                    ),
                });
            }
        }

        // --- deprecated-name ---
        if !dep_allowed && DEPRECATED_NAMES.contains(&text) {
            out.push(Violation {
                rule: "deprecated-name",
                path: path.to_string(),
                line: t.line,
                col: t.col,
                msg: format!(
                    "deprecated entry point `{text}`; use the `FitPlan` builder (the shims \
                     in `coordinator/` are the only allowed references)"
                ),
            });
        }
    }
    out
}

/// Recursively collect `.rs` files under the scan dirs, repo-relative
/// with forward slashes, sorted.
pub fn scan_files(root: &Path) -> Vec<(String, PathBuf)> {
    let mut out = Vec::new();
    for dir in SCAN_DIRS {
        let base = root.join(dir);
        collect_rs(&base, &mut out);
    }
    out.sort();
    out.into_iter()
        .map(|p| {
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .to_string_lossy()
                .replace('\\', "/");
            (rel, p)
        })
        .collect()
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out);
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
}

/// Parsed baseline: `(rule, path) -> grandfathered count`.
pub type Baseline = BTreeMap<(String, String), usize>;

/// Parse the baseline file format: `<rule> <path> <count>` per line,
/// `#` comments and blanks ignored.
pub fn parse_baseline(text: &str) -> Baseline {
    let mut map = Baseline::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(rule), Some(path), Some(count)) = (it.next(), it.next(), it.next()) else {
            continue;
        };
        if let Ok(c) = count.parse::<usize>() {
            map.insert((rule.to_string(), path.to_string()), c);
        }
    }
    map
}

/// Serialize a baseline (sorted, with the shrink-only header).
pub fn render_baseline(map: &Baseline) -> String {
    let mut out = String::from(
        "# pds-lint baseline — pre-existing violations, grandfathered by count.\n\
         # Counts may only shrink: fix sites, then `cargo run -p pds-lint -- --write-baseline`.\n\
         # format: <rule> <repo-relative-path> <count>\n",
    );
    for ((rule, path), count) in map {
        if *count > 0 {
            out.push_str(&format!("{rule} {path} {count}\n"));
        }
    }
    out
}

/// Outcome of a lint run.
pub struct Report {
    /// Violations exceeding the baseline, grouped order by (rule, path).
    pub violations: Vec<Violation>,
    /// Count of violations suppressed by the baseline.
    pub baselined: usize,
    /// Baseline entries whose actual count shrank (or whose file is
    /// gone) — failures under `--deny-stale`.
    pub stale: Vec<String>,
    pub files_scanned: usize,
    /// Actual per-(rule, path) counts — the input to `--write-baseline`.
    pub actual: Baseline,
}

/// Lint the whole tree under `root` against `baseline`.
pub fn run(root: &Path, baseline: &Baseline) -> Report {
    let files = scan_files(root);
    let files_scanned = files.len();
    let mut by_key: BTreeMap<(String, String), Vec<Violation>> = BTreeMap::new();
    for (rel, abs) in &files {
        let Ok(src) = fs::read_to_string(abs) else {
            continue;
        };
        for v in lint_file(rel, &src) {
            by_key
                .entry((v.rule.to_string(), v.path.clone()))
                .or_default()
                .push(v);
        }
    }
    let mut actual = Baseline::new();
    for (key, vs) in &by_key {
        actual.insert(key.clone(), vs.len());
    }
    let mut violations = Vec::new();
    let mut baselined = 0usize;
    for (key, vs) in &by_key {
        let allowed = baseline.get(key).copied().unwrap_or(0);
        if vs.len() <= allowed {
            baselined += vs.len();
        } else {
            violations.extend(vs.iter().cloned());
        }
    }
    let mut stale = Vec::new();
    for ((rule, path), &allowed) in baseline {
        let have = actual.get(&(rule.clone(), path.clone())).copied().unwrap_or(0);
        if have < allowed {
            stale.push(format!(
                "{path}: {rule} baseline is stale ({allowed} grandfathered, {have} found) — \
                 run with --write-baseline to burn the debt down"
            ));
        }
    }
    Report { violations, baselined, stale, files_scanned, actual }
}

/// Ascend from `start` to the first directory containing `rust/src`.
pub fn find_root(start: &Path) -> Option<PathBuf> {
    let mut dir = start.to_path_buf();
    loop {
        if dir.join("rust/src").is_dir() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}
