//! Per-rule fixture tests: each fixture under `tests/fixtures/` is a
//! small Rust source (data, never compiled) with `VIOLATION line N`
//! markers; the linter must find exactly those lines and nothing else.

use pds_lint::{lint_file, Violation};

fn lines_for(rule: &str, vs: &[Violation]) -> Vec<usize> {
    vs.iter().filter(|v| v.rule == rule).map(|v| v.line).collect()
}

#[test]
fn safety_contract_fixture() {
    let src = include_str!("fixtures/safety_contract.rs");
    let vs = lint_file("rust/src/fixture.rs", src);
    assert_eq!(lines_for("safety-contract", &vs), vec![14, 21]);
}

#[test]
fn safety_contract_applies_outside_src_too() {
    let vs = lint_file(
        "rust/benches/fixture.rs",
        "pub unsafe fn no_contract() {}\n",
    );
    assert_eq!(lines_for("safety-contract", &vs), vec![1]);
}

#[test]
fn lossy_cast_fixture() {
    let src = include_str!("fixtures/lossy_cast.rs");
    let vs = lint_file("rust/src/fixture.rs", src);
    assert_eq!(lines_for("lossy-cast", &vs), vec![4, 18]);
    // the rule is scoped to library code: same source under tests/ is clean
    assert!(lines_for("lossy-cast", &lint_file("rust/tests/fixture.rs", src)).is_empty());
}

#[test]
fn unwrap_fixture() {
    let src = include_str!("fixtures/unwrap.rs");
    let vs = lint_file("rust/src/fixture.rs", src);
    assert_eq!(lines_for("unwrap", &vs), vec![4, 5]);
    assert!(lines_for("unwrap", &lint_file("examples/fixture.rs", src)).is_empty());
}

#[test]
fn atomic_ordering_fixture() {
    let src = include_str!("fixtures/atomic_ordering.rs");
    let vs = lint_file("rust/src/serve/fixture.rs", src);
    assert_eq!(lines_for("atomic-ordering", &vs), vec![10, 11]);
    // scoped to the daemon: the same source elsewhere in src is exempt
    assert!(lines_for("atomic-ordering", &lint_file("rust/src/fixture.rs", src)).is_empty());
}

#[test]
fn deprecated_name_fixture() {
    let src = include_str!("fixtures/deprecated_name.rs");
    let vs = lint_file("rust/src/fixture.rs", src);
    assert_eq!(lines_for("deprecated-name", &vs), vec![4, 5, 17]);
    // the compatibility shims are the one place the names may appear
    assert!(lines_for(
        "deprecated-name",
        &lint_file("rust/src/coordinator/driver.rs", src)
    )
    .is_empty());
}

#[test]
fn lexer_strips_strings_and_char_literals() {
    // every would-be violation here lives inside a literal
    let src = r#"
pub fn f() -> &'static str {
    let _c = 'u'; // a char, not a lifetime
    "x.unwrap() as u32 run_pca_stream unsafe {"
}
"#;
    let vs = lint_file("rust/src/fixture.rs", src);
    assert!(vs.is_empty(), "{vs:?}");
}

#[test]
fn lexer_handles_raw_strings() {
    let src = "pub fn f() -> String { format!(r#\"as u32 .unwrap()\"#) }\n";
    let vs = lint_file("rust/src/fixture.rs", src);
    assert!(vs.is_empty(), "{vs:?}");
}

#[test]
fn multiline_cfg_test_extent_is_tracked() {
    let src = "#[cfg(test)]\nmod tests {\n    fn f(v: Option<u32>) -> u32 {\n        v.unwrap()\n    }\n}\nfn lib(v: Option<u32>) -> u32 { v.unwrap() }\n";
    let vs = lint_file("rust/src/fixture.rs", src);
    assert_eq!(lines_for("unwrap", &vs), vec![7]);
}
