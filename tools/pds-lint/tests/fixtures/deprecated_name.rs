// Fixture: deprecated-name rule. Not compiled — lexed by lint_rules.rs.

pub fn calls_old_api() {
    run_pca_stream(); // VIOLATION line 4
    run_sparsified_kmeans_from_store(); // VIOLATION line 5
    // mentions in comments are fine: run_two_pass_stream
    fit_plan_api();
}

fn fit_plan_api() {}

// even test code may not resurrect the old names
#[cfg(test)]
mod tests {
    #[test]
    fn old_name_in_test() {
        super::run_compress_to_store(); // VIOLATION line 17
    }
}
