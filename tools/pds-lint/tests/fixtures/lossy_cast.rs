// Fixture: lossy-cast rule. Not compiled — lexed by lint_rules.rs.

pub fn casts(x: u64, f: f64) -> usize {
    let a = x as u32; // VIOLATION line 4
    let b = x as usize; // lint:allow(lossy-cast) — same-line marker
    // lint:allow(lossy-cast) — marker in the comment run
    // immediately above also covers the site
    let c = f as f32;
    let d = f; // a plain `as` path rename below must not trip the rule
    let _ = (a, b, c, d);
    helper()
}

use std::collections::BTreeMap as Map;

fn helper() -> usize {
    let v = 1.5_f64;
    v as usize // VIOLATION line 18
}

#[cfg(test)]
mod tests {
    pub fn in_test_code() -> u32 {
        7.9_f64 as u32 // casts in test code are not flagged
    }
}
