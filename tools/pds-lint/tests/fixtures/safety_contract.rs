// Fixture: safety-contract rule. Not compiled — lexed by lint_rules.rs.

/// Has a contract in the doc.
///
/// # Safety
/// Caller guarantees `p` is valid for reads of `n` elements.
#[allow(unused)]
pub unsafe fn covered_by_doc(p: *const u8, n: usize) {}

// SAFETY: contract may also live in a plain comment run
// spanning several lines above the declaration.
pub unsafe fn covered_by_comment() {}

pub unsafe fn missing_contract() {} // VIOLATION line 14

fn blocks() {
    let x = [1u8];
    // SAFETY: index 0 is in bounds by construction.
    let _a = unsafe { *x.get_unchecked(0) };
    let _b = unsafe { *x.get_unchecked(0) }; // SAFETY: same-line comment also counts
    let _c = unsafe { *x.get_unchecked(0) }; // VIOLATION line 21: comment lacks the magic word
}

unsafe impl Send for Wrapper {}

struct Wrapper(*const u8);

#[cfg(test)]
mod tests {
    pub unsafe fn in_test_code_is_ignored() {}
}
