// Fixture: unwrap rule. Not compiled — lexed by lint_rules.rs.

pub fn panicky(v: Option<u32>, r: Result<u32, ()>) -> u32 {
    let a = v.unwrap(); // VIOLATION line 4
    let b = r.expect("should not fail"); // VIOLATION line 5
    a + b
}

pub fn fine(v: Option<u32>) -> u32 {
    // unwrap_or / unwrap_or_else are different identifiers: allowed
    v.unwrap_or(0) + v.unwrap_or_else(|| 1)
}

/// Doc examples are comments, so `v.unwrap()` here is not flagged.
pub fn documented() {}

#[cfg(test)]
mod tests {
    #[test]
    fn asserts_with_unwrap() {
        assert_eq!(Some(3).unwrap(), 3); // test code: allowed
        Result::<u32, ()>::Ok(1).expect("fine in tests");
    }
}
