// Fixture: atomic-ordering rule (scoped to rust/src/serve/).
// Not compiled — lexed by lint_rules.rs.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

pub fn orderings(flag: &AtomicBool, n: &AtomicU64) {
    // SeqCst: must observe a store from any other thread
    flag.store(true, Ordering::SeqCst);
    n.fetch_add(1, Ordering::Relaxed); // Relaxed: monotonic counter, no ordering needed
    n.fetch_add(1, Ordering::Relaxed); // VIOLATION line 10: comment does not name the ordering
    flag.load(Ordering::Acquire); // VIOLATION line 11
}

pub fn not_atomic(a: u32, b: u32) -> std::cmp::Ordering {
    // cmp::Ordering variants are not atomic orderings: never flagged
    a.cmp(&b)
}

#[cfg(test)]
mod tests {
    use super::*;
    #[test]
    fn test_code_is_exempt() {
        let f = AtomicBool::new(false);
        f.store(true, Ordering::SeqCst);
    }
}
