//! Self-check: the real tree must be clean against the committed
//! baseline — no (rule, file) pair may exceed its grandfathered count.
//! This is the tier-1 guard; CI additionally runs `--deny-stale` so the
//! counts can only shrink.

use std::path::Path;

use pds_lint::{parse_baseline, run, Baseline, BASELINE_FILE};

fn repo_root() -> &'static Path {
    // tools/pds-lint -> repo root is two levels up
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .and_then(Path::parent)
        .expect("tools/pds-lint sits two levels under the repo root")
}

#[test]
fn tree_is_clean_against_baseline() {
    let root = repo_root();
    let baseline: Baseline = match std::fs::read_to_string(root.join(BASELINE_FILE)) {
        Ok(text) => parse_baseline(&text),
        Err(_) => Baseline::new(),
    };
    let report = run(root, &baseline);
    assert!(
        report.files_scanned > 50,
        "scan scope looks broken: only {} files found",
        report.files_scanned
    );
    let rendered: Vec<String> = report.violations.iter().map(|v| v.render()).collect();
    assert!(
        report.violations.is_empty(),
        "pds-lint found {} non-baselined violation(s):\n{}",
        report.violations.len(),
        rendered.join("\n")
    );
}

#[test]
fn hardened_subsystems_carry_no_baselined_debt() {
    // The PR that introduced the linter also burned the debt out of the
    // store, the daemon's transport, and the artifact codec; those
    // files must stay at zero, not merely under a baseline.
    let root = repo_root();
    let report = run(root, &Baseline::new());
    let hardened = [
        "rust/src/store/",
        "rust/src/serve/transport.rs",
        "rust/src/distributed/artifact.rs",
        "rust/src/convert.rs",
    ];
    let offenders: Vec<String> = report
        .violations
        .iter()
        .filter(|v| hardened.iter().any(|h| v.path.starts_with(h)))
        .map(|v| v.render())
        .collect();
    assert!(
        offenders.is_empty(),
        "hardened files regressed:\n{}",
        offenders.join("\n")
    );
}

#[test]
fn safety_contracts_and_orderings_are_complete() {
    // Three rules are at zero across the whole tree and must stay there:
    // missing SAFETY contracts, unjustified atomic orderings, and
    // deprecated-name references are never baselined.
    let root = repo_root();
    let report = run(root, &Baseline::new());
    let zero_rules = ["safety-contract", "atomic-ordering", "deprecated-name"];
    let offenders: Vec<String> = report
        .violations
        .iter()
        .filter(|v| zero_rules.contains(&v.rule))
        .map(|v| v.render())
        .collect();
    assert!(
        offenders.is_empty(),
        "zero-tolerance rule regressed:\n{}",
        offenders.join("\n")
    );
}
