#!/usr/bin/env python3
"""Perf regression gate for BENCH_hotpaths.json.

Compares a freshly measured bench JSON against the committed baseline.
Machines differ in absolute speed, so the gate is self-normalizing:

  1. intersect the two `results` lists by row name,
  2. ratio_i = fresh_median_i / committed_median_i for each shared row,
  3. norm = median(ratio_i)  -- the overall speed of this machine
     relative to the baseline host,
  4. a row FAILS if ratio_i > norm * (1 + tolerance): it got more than
     `tolerance` slower *relative to the rest of the suite*, which is
     what a code regression (as opposed to a slow runner) looks like.

It also enforces every entry of the fresh file's `checks` list
(`value <= tolerance` per entry -- numeric invariants such as the
f32-vs-f64 explained-variance parity).

Usage:
  scripts/bench_gate.py COMMITTED.json FRESH.json [--tolerance 0.25]

Exit status 0 = pass, 1 = regression or failed check, 2 = bad input.
The 25% default tolerance is documented in rust/EXPERIMENTS.md §Perf log.
"""

import argparse
import json
import statistics
import sys

MIN_SHARED_ROWS = 5  # an empty/tiny intersection must not silently pass


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot load {path}: {e}", file=sys.stderr)
        sys.exit(2)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("committed", help="baseline JSON (checked into the repo)")
    ap.add_argument("fresh", help="freshly measured JSON")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.25,
        help="allowed slowdown relative to the suite-wide norm (default 0.25)",
    )
    args = ap.parse_args()

    committed = load(args.committed)
    fresh = load(args.fresh)
    base = {r["name"]: r for r in committed.get("results", [])}
    shared = [r for r in fresh.get("results", []) if r["name"] in base]
    if len(shared) < MIN_SHARED_ROWS:
        print(
            f"error: only {len(shared)} row(s) shared between {args.committed} and "
            f"{args.fresh} (need >= {MIN_SHARED_ROWS}); row names out of sync?",
            file=sys.stderr,
        )
        sys.exit(2)

    ratios = {r["name"]: r["median_s"] / base[r["name"]]["median_s"] for r in shared}
    norm = statistics.median(ratios.values())
    limit = norm * (1.0 + args.tolerance)
    print(
        f"bench gate: {len(shared)} shared rows, machine norm {norm:.3f}x baseline, "
        f"per-row limit {limit:.3f}x (tolerance {args.tolerance:.0%})\n"
    )
    print(f"{'row':<56} {'base':>10} {'fresh':>10} {'ratio':>7}  status")
    failed = []
    for r in shared:
        name = r["name"]
        ratio = ratios[name]
        ok = ratio <= limit
        if not ok:
            failed.append(name)
        print(
            f"{name:<56} {base[name]['median_s']:>10.3e} {r['median_s']:>10.3e} "
            f"{ratio:>6.2f}x  {'ok' if ok else 'REGRESSED'}"
        )

    print()
    bad_checks = []
    for c in fresh.get("checks", []):
        ok = c["value"] <= c["tolerance"]
        if not ok:
            bad_checks.append(c["name"])
        print(
            f"check {c['name']}: value {c['value']:.3e} vs tolerance "
            f"{c['tolerance']:.1e} -- {'ok' if ok else 'FAILED'}"
        )
    # every committed check must still be emitted by the fresh run
    committed_checks = {c["name"] for c in committed.get("checks", [])}
    fresh_checks = {c["name"] for c in fresh.get("checks", [])}
    for missing in sorted(committed_checks - fresh_checks):
        bad_checks.append(missing)
        print(f"check {missing}: MISSING from fresh run")

    if failed or bad_checks:
        print(
            f"\nFAIL: {len(failed)} regressed row(s), {len(bad_checks)} failed check(s)",
            file=sys.stderr,
        )
        sys.exit(1)
    print("\nPASS")


if __name__ == "__main__":
    main()
