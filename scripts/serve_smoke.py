#!/usr/bin/env python3
"""End-to-end smoke test for the `pds serve` daemon (pipe + TCP).

Drives the real binary with newline-delimited JSON:

  1. Full lifecycle (pipe): ingest -> flush -> refresh -> query ->
     stats -> shutdown must round-trip, exit 0, and leave a store that
     `pds store-info` (which replays the CRC'd manifest) opens with
     every ingested column.
  2. Typed errors: a malformed request gets `{"ok":false,"code":...}`
     and the daemon keeps serving.
  3. Crash safety: SIGKILL mid-stream (no cleanup of any kind runs)
     must leave the last durable checkpoint reopenable.
  4. TCP transport: the same lifecycle over `--listen 127.0.0.1:0`,
     plus `query_batch` (results bit-identical to single queries) and
     the connection cap (`--conn-slots 1`: a second connection gets one
     typed `backpressure` line, then EOF).
  5. Warm restart: kill a refreshed daemon, respawn it on the same
     store, and the first query must answer from the persisted snapshot
     at the pre-kill model version.

Usage:
  scripts/serve_smoke.py PATH/TO/pds

Exit status 0 = pass, 1 = failure.
"""

import json
import os
import random
import re
import shutil
import socket
import subprocess
import sys
import tempfile
import threading

P = 16  # sample dimension for the whole smoke run


def batch(n, seed):
    rng = random.Random(seed)
    return {
        "cmd": "ingest",
        "samples": [[rng.gauss(0, 1) for _ in range(P)] for _ in range(n)],
    }


SERVE_ARGS = [
    "--p", str(P),
    "--shard-cols", "8",
    # refresh only on request: no background cycle racing the test
    "--refresh-ms", "3600000",
    "--timeout-ms", "60000",
]


class Serve:
    """One serve session over the child's stdin/stdout pipes."""

    def __init__(self, pds, store, task):
        self.proc = subprocess.Popen(
            [pds, "serve", "--store", store, "--task", task, *SERVE_ARGS],
            stdin=subprocess.PIPE,
            stdout=subprocess.PIPE,
            stderr=subprocess.DEVNULL,
            text=True,
        )

    def request(self, obj):
        self.proc.stdin.write(json.dumps(obj) + "\n")
        self.proc.stdin.flush()
        line = self.proc.stdout.readline()
        assert line, f"daemon closed the pipe on {obj.get('cmd')!r}"
        return json.loads(line)

    def ok(self, obj):
        resp = self.request(obj)
        assert resp.get("ok") is True, f"{obj.get('cmd')}: {resp}"
        return resp


class TcpServe:
    """One serve session over `--listen 127.0.0.1:0` (ephemeral port,
    parsed from the daemon's `listening on` stderr line)."""

    def __init__(self, pds, store, task, conn_slots):
        self.proc = subprocess.Popen(
            [
                pds, "serve",
                "--store", store,
                "--task", task,
                "--listen", "127.0.0.1:0",
                "--conn-slots", str(conn_slots),
                *SERVE_ARGS,
            ],
            stdin=subprocess.DEVNULL,
            stdout=subprocess.DEVNULL,
            stderr=subprocess.PIPE,
            text=True,
        )
        line = self.proc.stderr.readline()
        m = re.search(r"listening on .*:(\d+)", line)
        assert m, f"no listening line from the daemon: {line!r}"
        self.port = int(m.group(1))
        # keep stderr drained (closing it would break the daemon's
        # final metrics dump); the banner above is all we parse
        threading.Thread(target=self.proc.stderr.read, daemon=True).start()

    def connect(self):
        return Conn(self.port)


class Conn:
    """One TCP connection speaking the newline-delimited protocol."""

    def __init__(self, port):
        self.sock = socket.create_connection(("127.0.0.1", port), timeout=60)
        self.f = self.sock.makefile("rw", encoding="utf-8", newline="\n")

    def readline(self):
        return self.f.readline()

    def request(self, obj):
        self.f.write(json.dumps(obj) + "\n")
        self.f.flush()
        line = self.readline()
        assert line, f"daemon closed the connection on {obj.get('cmd')!r}"
        return json.loads(line)

    def ok(self, obj):
        resp = self.request(obj)
        assert resp.get("ok") is True, f"{obj.get('cmd')}: {resp}"
        return resp

    def close(self):
        try:
            self.f.close()
        finally:
            self.sock.close()


def assert_store_n(pds, store, expect_n):
    """`pds store-info` must open the store (manifest + CRCs intact) and
    report the expected column count."""
    out = subprocess.run(
        [pds, "store-info", "--store", store], capture_output=True, text=True
    )
    assert out.returncode == 0, f"store-info failed: {out.stderr}"
    assert re.search(rf"samples n\s*=\s*{expect_n}\b", out.stdout), (
        f"expected n={expect_n} in:\n{out.stdout}"
    )


def main():
    if len(sys.argv) != 2:
        print(__doc__, file=sys.stderr)
        return 1
    pds = sys.argv[1]
    root = tempfile.mkdtemp(prefix="pds_serve_smoke_")
    try:
        # 1) full lifecycle with a clean shutdown
        store = os.path.join(root, "lifecycle")
        s = Serve(pds, store, "pca")
        for seed in range(3):
            s.ok(batch(8, seed))
        flush = s.ok({"cmd": "flush"})
        assert flush["durable_cols"] == 24, flush
        refresh = s.ok({"cmd": "refresh"})
        version = refresh["model_version"]
        assert version >= 1, refresh

        rng = random.Random(99)
        q = s.ok({"cmd": "query", "sample": [rng.gauss(0, 1) for _ in range(P)]})
        assert q["model_version"] == version, q
        assert q["stale"] is False, q
        assert len(q["coords"]) > 0, q

        stats = s.ok({"cmd": "stats"})
        assert "metrics" in stats, stats

        # 2) typed errors, daemon stays up
        bad = s.request({"cmd": "teleport"})
        assert bad["ok"] is False and bad["code"] == "bad_request", bad
        bad = s.request({"cmd": "ingest", "samples": [[1.0, 2.0]]})
        assert bad["ok"] is False and bad["code"] == "bad_request", bad
        s.ok({"cmd": "stats"})  # still answering

        s.ok({"cmd": "shutdown"})
        assert s.proc.wait(timeout=120) == 0, "clean shutdown must exit 0"
        assert_store_n(pds, store, 24)

        # 3) SIGKILL mid-stream: recover at the last durable checkpoint
        store = os.path.join(root, "sigkill")
        s = Serve(pds, store, "kmeans")
        s.ok(batch(8, 0))
        s.ok(batch(8, 1))
        flush = s.ok({"cmd": "flush"})
        assert flush["durable_cols"] == 16, flush
        s.proc.kill()
        s.proc.wait(timeout=120)
        assert_store_n(pds, store, 16)

        # 4) TCP transport: lifecycle + query_batch + connection cap
        store = os.path.join(root, "tcp")
        t = TcpServe(pds, store, "pca", conn_slots=1)
        c = t.connect()
        for seed in range(3):
            c.ok(batch(8, seed))
        flush = c.ok({"cmd": "flush"})
        assert flush["durable_cols"] == 24, flush
        refresh = c.ok({"cmd": "refresh"})
        version = refresh["model_version"]
        assert version >= 1, refresh

        samples = [[random.Random(s0).gauss(0, 1) for _ in range(P)]
                   for s0 in (7, 8)]
        single = c.ok({"cmd": "query", "sample": samples[0]})
        qb = c.ok({"cmd": "query_batch", "samples": samples})
        assert qb["model_version"] == version, qb
        assert len(qb["results"]) == 2, qb
        assert qb["results"][0]["coords"] == single["coords"], (
            "batched query must be bit-identical to the single-sample path"
        )

        # with one slot busy, a second connection gets one typed
        # backpressure line and EOF
        c2 = t.connect()
        line = c2.readline()
        rejected = json.loads(line)
        assert rejected["ok"] is False and rejected["code"] == "backpressure", rejected
        assert c2.readline() == "", "rejected connection must be closed"
        c2.close()

        c.ok({"cmd": "shutdown"})
        c.close()
        assert t.proc.wait(timeout=120) == 0, "TCP shutdown must exit 0"
        assert_store_n(pds, store, 24)

        # 5) warm restart: the persisted snapshot answers the first query
        store = os.path.join(root, "warm")
        s = Serve(pds, store, "kmeans")
        s.ok(batch(8, 0))
        s.ok(batch(8, 1))
        flush = s.ok({"cmd": "flush"})
        assert flush["durable_cols"] == 16, flush
        refresh = s.ok({"cmd": "refresh"})
        version = refresh["model_version"]
        s.proc.kill()  # no graceful exit: the artifact must already be durable
        s.proc.wait(timeout=120)

        s = Serve(pds, store, "kmeans")
        q = s.ok({"cmd": "query", "sample": [rng.gauss(0, 1) for _ in range(P)]})
        assert q["model_version"] == version, f"warm start must keep the version: {q}"
        assert "cluster" in q, q
        s.ok({"cmd": "shutdown"})
        assert s.proc.wait(timeout=120) == 0, "warm-restart shutdown must exit 0"
        assert_store_n(pds, store, 16)

        print("serve smoke: PASS")
        return 0
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    sys.exit(main())
