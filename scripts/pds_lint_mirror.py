#!/usr/bin/env python3
"""Reference mirror of tools/pds-lint (same lexer + rules, line for line).

Used to cross-check the Rust linter and to (re)generate
pds-lint.baseline in environments without a Rust toolchain:

    python3 scripts/pds_lint_mirror.py [--write-baseline] [--deny-stale] [--list RULE]

The Rust binary (`cargo run -p pds-lint`) is authoritative; any
divergence between the two is a bug in this script.
"""
import os
import sys

SCAN_DIRS = ["rust/src", "rust/tests", "rust/benches", "examples"]
BASELINE_FILE = "pds-lint.baseline"

NUMERIC_TYPES = {
    "u8", "u16", "u32", "u64", "u128", "usize",
    "i8", "i16", "i32", "i64", "i128", "isize", "f32", "f64",
}
ATOMIC_ORDERINGS = {"Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"}
DEPRECATED_NAMES = {
    "run_pca_stream", "run_pca_sparse", "run_pca_from_store",
    "run_pca_krylov_stream", "run_pca_krylov_sparse", "run_pca_krylov_from_store",
    "run_sparsified_kmeans_stream", "run_sparsified_kmeans_sparse",
    "run_sparsified_kmeans_from_store", "run_two_pass_stream", "run_compress_to_store",
}
DEPRECATED_ALLOW = {
    "rust/src/coordinator/driver.rs",
    "rust/src/coordinator/krylov.rs",
    "rust/src/coordinator/mod.rs",
}


def lex(src):
    chars = src
    n = len(chars)
    n_lines = max(len(src.splitlines()), 1)
    tokens = []  # (text, line, col)
    comment_text = [""] * (n_lines + 1)
    has_comment = [False] * (n_lines + 1)
    has_code = [False] * (n_lines + 1)
    raw_lines = src.splitlines()

    def at(i):
        return chars[i] if i < n else "\0"

    i, line, col = 0, 1, 1
    while i < n:
        c = chars[i]
        if c == "\n":
            line += 1
            col = 1
            i += 1
            continue
        if c == "/" and at(i + 1) == "/":
            start = i
            while i < n and chars[i] != "\n":
                i += 1
            if line <= n_lines:
                comment_text[line - 1] += chars[start:i] + " "
                has_comment[line - 1] = True
            continue
        if c == "/" and at(i + 1) == "*":
            depth = 1
            i += 2
            col += 2
            seg = "/*"
            while i < n and depth > 0:
                if chars[i] == "\n":
                    if line <= n_lines:
                        comment_text[line - 1] += seg + " "
                        has_comment[line - 1] = True
                    seg = ""
                    line += 1
                    col = 1
                    i += 1
                    continue
                if chars[i] == "/" and at(i + 1) == "*":
                    depth += 1
                    seg += "/*"
                    i += 2
                    col += 2
                    continue
                if chars[i] == "*" and at(i + 1) == "/":
                    depth -= 1
                    seg += "*/"
                    i += 2
                    col += 2
                    continue
                seg += chars[i]
                i += 1
                col += 1
            if seg and line <= n_lines:
                comment_text[line - 1] += seg + " "
                has_comment[line - 1] = True
            continue
        if (c in "rb") and (at(i + 1) == '"' or at(i + 1) == "#" or (c == "b" and at(i + 1) == "r")):
            j = i + 1
            raw = c == "r"
            if c == "b" and at(j) == "r":
                raw = True
                j += 1
            hashes = 0
            while at(j) == "#":
                hashes += 1
                j += 1
            if at(j) == '"' and (raw or hashes == 0):
                if line <= n_lines:
                    has_code[line - 1] = True
                j += 1
                while True:
                    if j >= n:
                        break
                    d = chars[j]
                    if d == "\n":
                        line += 1
                        col = 1
                        j += 1
                        if line <= n_lines:
                            has_code[line - 1] = True
                        continue
                    if not raw and d == "\\":
                        j += 2
                        col += 2
                        continue
                    if d == '"':
                        k = j + 1
                        close = 0
                        while close < hashes and at(k) == "#":
                            close += 1
                            k += 1
                        if close == hashes:
                            j = k
                            col += 1 + hashes
                            break
                    j += 1
                    col += 1
                i = j
                continue
            # fall through to identifier lexing
        if c == '"':
            if line <= n_lines:
                has_code[line - 1] = True
            i += 1
            col += 1
            while i < n:
                d = chars[i]
                if d == "\\":
                    i += 2
                    col += 2
                    continue
                if d == "\n":
                    line += 1
                    col = 1
                    i += 1
                    if line <= n_lines:
                        has_code[line - 1] = True
                    continue
                i += 1
                col += 1
                if d == '"':
                    break
            continue
        if c == "'":
            c1 = at(i + 1)
            if (c1.isalpha() or c1 == "_") and at(i + 2) != "'":
                i += 1
                col += 1
                while i < n and (chars[i].isalnum() or chars[i] == "_"):
                    i += 1
                    col += 1
                continue
            if line <= n_lines:
                has_code[line - 1] = True
            i += 1
            col += 1
            while i < n:
                d = chars[i]
                if d == "\\":
                    i += 2
                    col += 2
                    continue
                i += 1
                col += 1
                if d == "'" or d == "\n":
                    if d == "\n":
                        line += 1
                        col = 1
                    break
            continue
        if c.isalpha() or c == "_":
            start = i
            start_col = col
            while i < n and (chars[i].isalnum() or chars[i] == "_"):
                i += 1
                col += 1
            if line <= n_lines:
                has_code[line - 1] = True
            tokens.append((chars[start:i], line, start_col))
            continue
        if c.isdigit():
            start = i
            start_col = col
            while i < n and (chars[i].isalnum() or chars[i] == "_"):
                i += 1
                col += 1
            if at(i) == "." and at(i + 1).isdigit():
                i += 1
                col += 1
                while i < n and (chars[i].isalnum() or chars[i] == "_"):
                    i += 1
                    col += 1
            if line <= n_lines:
                has_code[line - 1] = True
            tokens.append((chars[start:i], line, start_col))
            continue
        if c == ":" and at(i + 1) == ":":
            if line <= n_lines:
                has_code[line - 1] = True
            tokens.append(("::", line, col))
            i += 2
            col += 2
            continue
        if not c.isspace():
            if line <= n_lines:
                has_code[line - 1] = True
            tokens.append((c, line, col))
        i += 1
        col += 1

    comment_only = [has_comment[l] and not has_code[l] for l in range(n_lines)]
    return tokens, comment_text[:n_lines], comment_only, raw_lines


def test_ranges(tokens):
    ranges = []
    i = 0
    n = len(tokens)
    while i < n:
        if tokens[i][0] == "#" and i + 1 < n and tokens[i + 1][0] == "[":
            depth = 0
            j = i + 1
            while j < n:
                t = tokens[j][0]
                if t == "[":
                    depth += 1
                elif t == "]":
                    depth -= 1
                    if depth == 0:
                        break
                j += 1
            if j >= n:
                break
            inner = [t[0] for t in tokens[i + 2 : j]]
            is_test = (len(inner) > 0 and inner[0] == "cfg" and "test" in inner) or inner == ["test"]
            if is_test:
                k = j + 1
                while k + 1 < n and tokens[k][0] == "#" and tokens[k + 1][0] == "[":
                    d = 0
                    while k < n:
                        t = tokens[k][0]
                        if t == "[":
                            d += 1
                        elif t == "]":
                            d -= 1
                            if d == 0:
                                break
                        k += 1
                    k += 1
                d = 0
                end = n - 1
                while k < n:
                    t = tokens[k][0]
                    if t == "{" and d == 0:
                        b = 0
                        while k < n:
                            t2 = tokens[k][0]
                            if t2 == "{":
                                b += 1
                            elif t2 == "}":
                                b -= 1
                                if b == 0:
                                    break
                            k += 1
                        end = min(k, n - 1)
                        break
                    if t in "([{":
                        d += 1
                    elif t in ")]}":
                        d -= 1
                    elif t == ";" and d == 0:
                        end = k
                        break
                    k += 1
                if k >= n:
                    end = n - 1
                ranges.append((i, end))
                i = end + 1
                continue
        i += 1
    return ranges


def in_ranges(ranges, idx):
    return any(a <= idx <= b for a, b in ranges)


def comment_run_above(comment_text, comment_only, line):
    acc = ""
    l = line
    while l >= 2 and (l - 2 < len(comment_only) and comment_only[l - 2]):
        acc += comment_text[l - 2] + " "
        l -= 1
    return acc


def doc_run_above(comment_text, comment_only, raw_lines, line):
    l = line
    while l >= 2:
        raw = raw_lines[l - 2] if l - 2 < len(raw_lines) else ""
        t = raw.lstrip()
        if t == "" or t.startswith("#[") or t.startswith("#!["):
            l -= 1
            continue
        break
    return comment_run_above(comment_text, comment_only, l)


def lint_file(path, src):
    tokens, ctext, conly, rlines = lex(src)
    tests = test_ranges(tokens)
    out = []
    n = len(tokens)
    in_lib = path.startswith("rust/src/")
    in_serve = path.startswith("rust/src/serve/")
    dep_allowed = path in DEPRECATED_ALLOW

    for i in range(n):
        text, tline, tcol = tokens[i]

        def nxt(k):
            return tokens[i + k][0] if i + k < n else ""

        if text == "unsafe" and not in_ranges(tests, i):
            is_fn = nxt(1) == "fn" or (nxt(1) == "extern" and nxt(2) == "fn")
            is_block = nxt(1) == "{"
            if is_fn:
                doc = doc_run_above(ctext, conly, rlines, tline)
                if "SAFETY" not in doc and "# Safety" not in doc:
                    out.append(("safety-contract", path, tline, tcol, "unsafe fn without contract"))
            elif is_block:
                same = ctext[tline - 1]
                above = comment_run_above(ctext, conly, tline)
                if "SAFETY" not in same and "SAFETY" not in above:
                    out.append(("safety-contract", path, tline, tcol, "unsafe block without SAFETY"))

        if in_lib and text == "as" and nxt(1) in NUMERIC_TYPES and not in_ranges(tests, i):
            same = ctext[tline - 1]
            above = comment_run_above(ctext, conly, tline)
            marker = "lint:allow(lossy-cast)"
            if marker not in same and marker not in above:
                out.append(("lossy-cast", path, tline, tcol, f"as {nxt(1)}"))

        if in_lib and text == "." and not in_ranges(tests, i):
            is_unwrap = nxt(1) == "unwrap" and nxt(2) == "(" and nxt(3) == ")"
            is_expect = nxt(1) == "expect" and nxt(2) == "("
            if is_unwrap or is_expect:
                out.append(("unwrap", path, tokens[i + 1][1], tokens[i + 1][2], f".{nxt(1)}"))

        if (
            in_serve
            and text == "Ordering"
            and nxt(1) == "::"
            and nxt(2) in ATOMIC_ORDERINGS
            and not in_ranges(tests, i)
        ):
            ord_ = nxt(2)
            same = ctext[tline - 1]
            above = comment_run_above(ctext, conly, tline)
            if ord_ not in same and ord_ not in above:
                out.append(("atomic-ordering", path, tline, tcol, f"Ordering::{ord_} unjustified"))

        if not dep_allowed and text in DEPRECATED_NAMES:
            out.append(("deprecated-name", path, tline, tcol, text))
    return out


def scan_files(root):
    files = []
    for d in SCAN_DIRS:
        base = os.path.join(root, d)
        for dirpath, _, names in os.walk(base):
            for nm in names:
                if nm.endswith(".rs"):
                    files.append(os.path.join(dirpath, nm))
    files.sort()
    return [(os.path.relpath(p, root).replace(os.sep, "/"), p) for p in files]


def main():
    args = sys.argv[1:]
    write = "--write-baseline" in args
    deny_stale = "--deny-stale" in args
    list_rule = args[args.index("--list") + 1] if "--list" in args else None
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

    baseline = {}
    bpath = os.path.join(root, BASELINE_FILE)
    if os.path.exists(bpath):
        for line in open(bpath):
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            parts = line.split()
            if len(parts) >= 3:
                baseline[(parts[0], parts[1])] = int(parts[2])

    by_key = {}
    files = scan_files(root)
    for rel, p in files:
        src = open(p, encoding="utf-8").read()
        for v in lint_file(rel, src):
            by_key.setdefault((v[0], v[1]), []).append(v)

    if list_rule:
        for (rule, path), vs in sorted(by_key.items()):
            if rule == list_rule:
                for v in vs:
                    print(f"{path}:{v[2]}:{v[3]}: {v[4]}")
        return 0

    if write:
        lines = [
            "# pds-lint baseline — pre-existing violations, grandfathered by count.",
            "# Counts may only shrink: fix sites, then `cargo run -p pds-lint -- --write-baseline`.",
            "# format: <rule> <repo-relative-path> <count>",
        ]
        for (rule, path), vs in sorted(by_key.items()):
            if vs:
                lines.append(f"{rule} {path} {len(vs)}")
        open(bpath, "w").write("\n".join(lines) + "\n")
        total = sum(len(v) for v in by_key.values())
        print(f"wrote {bpath}: {total} violations across {len(by_key)} (rule,file) pairs")
        return 0

    violations = 0
    baselined = 0
    for key, vs in sorted(by_key.items()):
        allowed = baseline.get(key, 0)
        if len(vs) <= allowed:
            baselined += len(vs)
        else:
            for v in vs:
                print(f"{v[1]}:{v[2]}:{v[3]}: error[{v[0]}]: {v[4]}")
            violations += len(vs)
    stale = 0
    if deny_stale:
        for (rule, path), allowed in sorted(baseline.items()):
            have = len(by_key.get((rule, path), []))
            if have < allowed:
                print(f"error[stale-baseline]: {path}: {rule} {allowed} -> {have}")
                stale += 1
    print(f"{len(files)} files scanned, {violations} violations, {baselined} baselined, {stale} stale")
    return 1 if (violations or stale) else 0


if __name__ == "__main__":
    sys.exit(main())
