//! Compress once, analyze many: the persistent sparse store workflow.
//!
//! The expensive part of the paper's pipeline — one pass over the raw
//! data through the ROS + sampling operator — is paid exactly once here
//! and its output is persisted as a sharded sparse store
//! (`docs/FORMAT.md`). Every later analysis (K-means, PCA, re-runs with
//! different k, ...) streams the compressed shards from disk through the
//! `FitPlan` session API and never touches the raw data again: zero raw
//! passes, and results bit-identical to the in-memory streaming
//! pipeline. The final fit shows the fully out-of-core K-means solver
//! (`Solver::Stream`), whose working set is just the reader's memory
//! budget.
//!
//! Run: `cargo run --release --example compress_once [n]`

use std::time::Instant;

use pds::coordinator::{FitPlan, MatSource, Solver, StreamConfig};
use pds::data::gaussian_blobs;
use pds::kmeans::KmeansOpts;
use pds::metrics::clustering_accuracy;
use pds::rng::Pcg64;
use pds::sampling::SparsifyConfig;
use pds::store::SparseStoreReader;
use pds::transform::TransformKind;

fn main() -> pds::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(20_000);
    let (p, k, gamma) = (256usize, 4usize, 0.1);
    let dir = std::env::temp_dir().join(format!("pds_compress_once_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let mut rng = Pcg64::seed(12);
    let d = gaussian_blobs(p, n, k, 0.08, &mut rng);
    let scfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed: 5 };
    let stream = StreamConfig { workers: 2, queue_depth: 4, chunk_cols: 2048 };

    // ---- compress ONCE: one pass over the raw data ---------------------
    let t0 = Instant::now();
    let mut src = MatSource::new(&d.data, 2048);
    let creport = FitPlan::compress()
        .stream(&mut src, scfg)
        .store_dir(&dir)
        .shard_cols(4096)
        .stream_config(stream)
        .run()?;
    let manifest = creport.store_manifest().expect("compress plan");
    println!(
        "compressed {} samples into {} shards in {:.2}s ({:.1} MB sparse vs {:.1} MB dense, \
         {} raw pass)",
        manifest.n,
        manifest.shards.len(),
        t0.elapsed().as_secs_f64(),
        manifest.payload_bytes() as f64 / (1024.0 * 1024.0),
        (n * p * 8) as f64 / (1024.0 * 1024.0),
        creport.raw_passes
    );

    // ---- analyze MANY: every fit below reads only the store ------------
    let opts = KmeansOpts { n_init: 3, ..Default::default() };
    let mut store = SparseStoreReader::open(&dir)?;
    let t1 = Instant::now();
    let kreport = FitPlan::kmeans()
        .store(&mut store)
        .k(k)
        .kmeans_opts(opts)
        .workers(2)
        .run()?;
    let model = kreport.kmeans_model().expect("kmeans plan");
    let acc = clustering_accuracy(&model.result.assign, &d.labels, k);
    println!(
        "K-means from store:  accuracy {acc:.4}, {} iterations, {:.2}s, raw passes: {}",
        model.result.iterations,
        t1.elapsed().as_secs_f64(),
        kreport.raw_passes
    );

    store.rewind();
    let t2 = Instant::now();
    let preport = FitPlan::pca().store(&mut store).topk(5).workers(2).run()?;
    let pca = preport.pca_fit().expect("pca plan");
    println!(
        "PCA from store:      top eigenvalue {:.3}, {:.2}s, raw passes: {}",
        pca.pca.eigenvalues[0],
        t2.elapsed().as_secs_f64(),
        preport.raw_passes
    );

    // ---- out-of-core: the streaming K-means solver under a tight
    //      memory budget (one sparse pass per Lloyd iteration) ----------
    let mut budgeted = SparseStoreReader::open(&dir)?.with_memory_budget(256 * 1024);
    let t3 = Instant::now();
    let sreport = FitPlan::kmeans()
        .store(&mut budgeted)
        .k(k)
        .kmeans_opts(opts)
        .solver(Solver::Stream)
        .run()?;
    let smodel = sreport.kmeans_model().expect("kmeans plan");
    println!(
        "K-means, stream solver (256 KiB reader budget): {:.2}s, raw passes: {}, sparse \
         passes: {}",
        t3.elapsed().as_secs_f64(),
        sreport.raw_passes,
        sreport.sparse_passes
    );

    // ---- every path is bit-identical to the streaming pipeline ---------
    let mut src2 = MatSource::new(&d.data, 2048);
    let dreport = FitPlan::kmeans()
        .stream(&mut src2, scfg)
        .k(k)
        .kmeans_opts(opts)
        .stream_config(stream)
        .run()?;
    let direct = dreport.kmeans_model().expect("kmeans plan");
    for (name, got) in [("store", model), ("stream-solver", smodel)] {
        assert_eq!(got.result.assign, direct.result.assign, "{name}: assignments diverged");
        assert_eq!(
            got.result.objective.to_bits(),
            direct.result.objective.to_bits(),
            "{name}: objective diverged"
        );
        for (a, b) in got
            .result
            .centers
            .as_slice()
            .iter()
            .zip(direct.result.centers.as_slice())
        {
            assert_eq!(a.to_bits(), b.to_bits(), "{name}: centers diverged");
        }
    }
    println!("store + out-of-core fits are bit-identical to the streaming fit ✓");

    std::fs::remove_dir_all(&dir).ok();
    println!("compress_once OK");
    Ok(())
}
