//! Compress once, analyze many: the persistent sparse store workflow.
//!
//! The expensive part of the paper's pipeline — one pass over the raw
//! data through the ROS + sampling operator — is paid exactly once here
//! and its output is persisted as a sharded sparse store
//! (`docs/FORMAT.md`). Every later analysis (K-means, PCA, re-runs with
//! different k, ...) streams the compressed shards from disk and never
//! touches the raw data again: zero raw passes, and results bit-identical
//! to the in-memory streaming pipeline.
//!
//! Run: `cargo run --release --example compress_once [n]`

use std::time::Instant;

use pds::coordinator::{
    run_compress_to_store, run_pca_from_store, run_sparsified_kmeans_from_store,
    run_sparsified_kmeans_stream, MatSource, StreamConfig,
};
use pds::data::gaussian_blobs;
use pds::kmeans::{KmeansOpts, NativeAssigner};
use pds::metrics::clustering_accuracy;
use pds::rng::Pcg64;
use pds::sampling::SparsifyConfig;
use pds::store::SparseStoreReader;
use pds::transform::TransformKind;

fn main() -> pds::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(20_000);
    let (p, k, gamma) = (256usize, 4usize, 0.1);
    let dir = std::env::temp_dir().join(format!("pds_compress_once_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let mut rng = Pcg64::seed(12);
    let d = gaussian_blobs(p, n, k, 0.08, &mut rng);
    let scfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed: 5 };
    let stream = StreamConfig { workers: 2, queue_depth: 4, chunk_cols: 2048 };

    // ---- compress ONCE: one pass over the raw data ---------------------
    let t0 = Instant::now();
    let mut src = MatSource::new(&d.data, 2048);
    let (manifest, creport) =
        run_compress_to_store(&mut src, scfg, &dir, 4096, stream, true)?;
    println!(
        "compressed {} samples into {} shards in {:.2}s ({:.1} MB sparse vs {:.1} MB dense, \
         {} raw pass)",
        manifest.n,
        manifest.shards.len(),
        t0.elapsed().as_secs_f64(),
        manifest.payload_bytes() as f64 / (1024.0 * 1024.0),
        (n * p * 8) as f64 / (1024.0 * 1024.0),
        creport.passes
    );

    // ---- analyze MANY: every fit below reads only the store ------------
    let opts = KmeansOpts { n_init: 3, ..Default::default() };
    let mut store = SparseStoreReader::open(&dir)?;
    let t1 = Instant::now();
    let (model, kreport) =
        run_sparsified_kmeans_from_store(&mut store, k, opts, &NativeAssigner, 2)?;
    let acc = clustering_accuracy(&model.result.assign, &d.labels, k);
    println!(
        "K-means from store:  accuracy {acc:.4}, {} iterations, {:.2}s, raw passes: {}",
        model.result.iterations,
        t1.elapsed().as_secs_f64(),
        kreport.passes
    );

    store.rewind();
    let t2 = Instant::now();
    let (pca, preport) = run_pca_from_store(&mut store, 5, 2)?;
    println!(
        "PCA from store:      top eigenvalue {:.3}, {:.2}s, raw passes: {}",
        pca.pca.eigenvalues[0],
        t2.elapsed().as_secs_f64(),
        preport.passes
    );

    // ---- the store fit is bit-identical to the streaming pipeline ------
    let mut src2 = MatSource::new(&d.data, 2048);
    let (direct, _) = run_sparsified_kmeans_stream(
        &mut src2,
        scfg,
        k,
        opts,
        &NativeAssigner,
        stream,
        true,
    )?;
    assert_eq!(model.result.assign, direct.result.assign, "assignments diverged");
    assert_eq!(
        model.result.objective.to_bits(),
        direct.result.objective.to_bits(),
        "objective diverged"
    );
    for (a, b) in model
        .result
        .centers
        .as_slice()
        .iter()
        .zip(direct.result.centers.as_slice())
    {
        assert_eq!(a.to_bits(), b.to_bits(), "centers diverged");
    }
    println!("store fit is bit-identical to the streaming fit ✓");

    std::fs::remove_dir_all(&dir).ok();
    println!("compress_once OK");
    Ok(())
}
