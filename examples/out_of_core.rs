//! Out-of-core scenario (the paper's §VII.C, Table IV): the dataset lives
//! on disk in the PDS1 chunk store; the coordinator streams it through
//! the bounded-queue pipeline so peak memory is O(compressed size +
//! one chunk), never O(raw data).
//!
//! Run: `cargo run --release --example out_of_core [n]`

use std::time::Instant;

use pds::coordinator::{FitPlan, StoreSource, StreamConfig};
use pds::data::{ChunkStore, ChunkStoreReader, DigitConfig, DigitStream, DIGIT_P};
use pds::kmeans::KmeansOpts;
use pds::metrics::clustering_accuracy;
use pds::sampling::SparsifyConfig;
use pds::transform::TransformKind;

fn main() -> pds::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(50_000);
    let gamma = 0.05;
    let chunk_cols = 8192;
    let path = std::env::temp_dir().join(format!("pds_ooc_example_{}", std::process::id()));

    // stage the dataset on disk (f32, chunked)
    let stream = DigitStream::new(DigitConfig { seed: 3, ..Default::default() });
    let t0 = Instant::now();
    {
        let mut store = ChunkStore::create(&path, DIGIT_P, chunk_cols)?;
        let mut start = 0usize;
        while start < n {
            let cols = (n - start).min(chunk_cols);
            store.append(&stream.chunk(start, cols))?;
            start += cols;
        }
        store.finish()?;
    }
    let disk_mb = (n * DIGIT_P * 4) as f64 / (1024.0 * 1024.0);
    println!(
        "staged {n} samples ({disk_mb:.0} MB f32) at {} in {:.1}s",
        path.display(),
        t0.elapsed().as_secs_f64()
    );
    let raw_mb = (n * DIGIT_P * 8) as f64 / (1024.0 * 1024.0);
    let compressed_mb = {
        let m = (gamma * 1024.0f64).round(); // padded p = 1024
        (n as f64 * m * 12.0) / (1024.0 * 1024.0) // 8B value + 4B index
    };
    println!(
        "raw in-RAM size would be {raw_mb:.0} MB; compressed working set is {compressed_mb:.0} MB \
         (gamma={gamma})"
    );

    // stream → compress → cluster, one pass over disk
    let mut src = StoreSource::new(ChunkStoreReader::open(&path)?);
    let scfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed: 9 };
    let t0 = Instant::now();
    let report = FitPlan::kmeans()
        .stream(&mut src, scfg)
        .k(3)
        .kmeans_opts(KmeansOpts { n_init: 3, ..Default::default() })
        .stream_config(StreamConfig { workers: 1, queue_depth: 4, chunk_cols })
        .run()?;
    let model = report.kmeans_model().expect("kmeans plan");
    let total = t0.elapsed().as_secs_f64();
    std::fs::remove_file(&path).ok();

    let labels = stream.labels(0, n);
    let acc = clustering_accuracy(&model.result.assign, &labels, 3);
    println!(
        "\none-pass sparsified K-means: accuracy {acc:.4}, {} iterations, {total:.1}s total",
        model.result.iterations
    );
    println!(
        "  disk load {:.1}s | compress {:.1}s | kmeans {:.1}s | raw passes {}",
        report.timer.get("load"),
        report.timer.get("compress"),
        report.timer.get("kmeans"),
        report.raw_passes
    );
    println!("out_of_core OK");
    Ok(())
}
