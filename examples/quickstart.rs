//! Quickstart: compress a synthetic dataset once, then run *both*
//! downstream consumers (streaming PCA and sparsified K-means) from the
//! same compressed stream — the paper's core "one pass, many analyses"
//! workflow, driven entirely through the `FitPlan` session API.
//!
//! Run: `cargo run --release --example quickstart`

use pds::coordinator::{FitPlan, MatSource, StreamConfig};
use pds::data::gaussian_blobs;
use pds::kmeans::KmeansOpts;
use pds::metrics::clustering_accuracy;
use pds::pca::recovered_components;
use pds::rng::Pcg64;
use pds::sampling::SparsifyConfig;
use pds::transform::TransformKind;

fn main() -> pds::Result<()> {
    let (p, n, k) = (512usize, 20_000usize, 5usize);
    let gamma = 0.05;
    println!("quickstart: p={p} n={n} K={k} gamma={gamma} (keep {:.0}% of entries)", gamma * 100.0);

    let mut rng = Pcg64::seed(7);
    let d = gaussian_blobs(p, n, k, 0.05, &mut rng);
    let scfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed: 42 };

    // --- sparsified K-means (Algorithm 1): one pass, native engine ---
    let mut src = MatSource::new(&d.data, 2048);
    let report = FitPlan::kmeans()
        .stream(&mut src, scfg)
        .k(k)
        .kmeans_opts(KmeansOpts { n_init: 5, ..Default::default() })
        .stream_config(StreamConfig::default())
        .run()?;
    let model = report.kmeans_model().expect("kmeans plan");
    let acc = clustering_accuracy(&model.result.assign, &d.labels, k);
    println!(
        "\nsparsified K-means: accuracy {acc:.4}, {} iterations, raw passes {}",
        model.result.iterations, report.raw_passes
    );
    if let Some(bound) = report.center_bound.last() {
        println!("final-iteration center-error bound (Eq. 43): {bound:.3}");
    }
    for (name, secs) in report.timer.phases() {
        println!("  {name:<10} {secs:.3} s");
    }

    // --- streaming PCA from the same compression scheme ---
    let mut src = MatSource::new(&d.data, 2048);
    let report = FitPlan::pca().stream(&mut src, scfg).topk(k).run()?;
    let pca = report.pca_fit().expect("pca plan");
    println!("\nstreaming PCA: top-{k} eigenvalues {:?}", pca.pca.eigenvalues);
    // the blob centers span a k-dim subspace; check the PCs capture it
    let rec = recovered_components(&pca.pca.components, &d.centers, 0.5);
    println!("PCs aligned with cluster-center subspace: {rec}/{k} (loose .5 threshold)");
    println!("passes over raw data: {}", report.raw_passes);
    println!("\nquickstart OK");
    Ok(())
}
