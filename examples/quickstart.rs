//! Quickstart: compress a synthetic dataset once, then run *both*
//! downstream consumers (streaming PCA and sparsified K-means) from the
//! same compressed stream — the paper's core "one pass, many analyses"
//! workflow.
//!
//! Run: `cargo run --release --example quickstart`

use pds::coordinator::{run_pca_stream, run_sparsified_kmeans_stream, MatSource, StreamConfig};
use pds::data::gaussian_blobs;
use pds::kmeans::{KmeansOpts, NativeAssigner};
use pds::metrics::clustering_accuracy;
use pds::pca::recovered_components;
use pds::rng::Pcg64;
use pds::sampling::SparsifyConfig;
use pds::transform::TransformKind;

fn main() -> pds::Result<()> {
    let (p, n, k) = (512usize, 20_000usize, 5usize);
    let gamma = 0.05;
    println!("quickstart: p={p} n={n} K={k} gamma={gamma} (keep {:.0}% of entries)", gamma * 100.0);

    let mut rng = Pcg64::seed(7);
    let d = gaussian_blobs(p, n, k, 0.05, &mut rng);
    let scfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed: 42 };

    // --- sparsified K-means (Algorithm 1): one pass, native engine ---
    let mut src = MatSource::new(&d.data, 2048);
    let (model, report) = run_sparsified_kmeans_stream(
        &mut src,
        scfg,
        k,
        KmeansOpts { n_init: 5, ..Default::default() },
        &NativeAssigner,
        StreamConfig::default(),
        true,
    )?;
    let acc = clustering_accuracy(&model.result.assign, &d.labels, k);
    println!(
        "\nsparsified K-means: accuracy {acc:.4}, {} iterations, passes {}",
        model.result.iterations, report.passes
    );
    for (name, secs) in report.timer.phases() {
        println!("  {name:<10} {secs:.3} s");
    }

    // --- streaming PCA from the same compression scheme ---
    let mut src = MatSource::new(&d.data, 2048);
    let (pca, report) = run_pca_stream(&mut src, scfg, k, StreamConfig::default())?;
    println!("\nstreaming PCA: top-{k} eigenvalues {:?}", pca.pca.eigenvalues);
    // the blob centers span a k-dim subspace; check the PCs capture it
    let rec = recovered_components(&pca.pca.components, &d.centers, 0.5);
    println!("PCs aligned with cluster-center subspace: {rec}/{k} (loose .5 threshold)");
    println!("passes over raw data: {}", report.passes);
    println!("\nquickstart OK");
    Ok(())
}
