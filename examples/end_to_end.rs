//! END-TO-END DRIVER (the repo's mandated full-system validation).
//!
//! Exercises every layer on a realistic workload:
//!   L1/L2 — the AOT Pallas/JAX `assign` artifact executed via PJRT,
//!   runtime — artifact manifest, compile cache, literal marshalling,
//!   L3 — streaming coordinator (generator source → bounded queues →
//!         sparsifier workers), sparsified K-means (Algorithm 1), the
//!         2-pass refinement (Algorithm 2), and the standard K-means
//!         baseline for the headline metric.
//!
//! Workload: 60k synthetic 28×28 digit images (3 classes — the paper's
//! {0,3,9} setup), γ = 5%. Reports the paper's headline numbers:
//! accuracy vs the full-data baseline and the per-iteration speedup.
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `make artifacts && cargo run --release --example end_to_end`
//! (falls back to the native engine if artifacts are missing).

use std::time::Instant;

use pds::coordinator::{
    two_pass_refine_stream, FitPlan, GeneratorSource, StreamConfig,
};
use pds::data::{DigitConfig, DigitStream, DIGIT_P};
use pds::kmeans::{kmeans_dense, KmeansOpts, NativeAssigner, SparseAssigner};
use pds::metrics::clustering_accuracy;
use pds::runtime::{artifact_dir, XlaEngine};
use pds::sampling::SparsifyConfig;
use pds::transform::TransformKind;

fn main() -> pds::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20_000);
    let k = 3usize;
    let gamma = 0.05;
    println!("=== end-to-end: sparsified K-means on {n} digit images (p={DIGIT_P}, K={k}, gamma={gamma}) ===");

    let stream = DigitStream::new(DigitConfig { seed: 2026, ..Default::default() });
    let labels = stream.labels(0, n);
    let scfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed: 11 };
    let opts = KmeansOpts { n_init: 3, max_iters: 100, tol_frac: 0.0, seed: 1 };
    let stream_cfg = StreamConfig { workers: 1, queue_depth: 4, chunk_cols: 2048 };

    // Engine: PJRT if artifacts are present (proves the full 3-layer
    // stack), native otherwise.
    let xla = if artifact_dir().join("manifest.tsv").exists() {
        match XlaEngine::new(None) {
            Ok(e) => Some(e),
            Err(e) => {
                println!("(xla engine unavailable: {e}; using native)");
                None
            }
        }
    } else {
        println!("(artifacts not built; using native engine — run `make artifacts`)");
        None
    };
    let assigner: &dyn SparseAssigner = match &xla {
        Some(e) => e,
        None => &NativeAssigner,
    };

    // --- 1-pass sparsified K-means through the FitPlan session API ---
    let gen = DigitStream::new(DigitConfig { seed: 2026, ..Default::default() });
    let mut src = GeneratorSource::new(DIGIT_P, n, 2048, move |s, c| gen.chunk(s, c));
    let t0 = Instant::now();
    let report = FitPlan::kmeans()
        .stream(&mut src, scfg)
        .k(k)
        .kmeans_opts(opts)
        .assigner(assigner)
        .stream_config(stream_cfg)
        .run()?;
    let model = report.kmeans_model().expect("kmeans plan");
    let t_sparse = t0.elapsed().as_secs_f64();
    let acc1 = clustering_accuracy(&model.result.assign, &labels, k);
    println!(
        "\n[1-pass sparsified, engine={}] accuracy {acc1:.4}  iters {}  total {t_sparse:.1}s",
        report.engine, model.result.iterations
    );
    if let Some(bound) = report.center_bound.last() {
        println!("   final-iteration center-error bound (Eq. 43): {bound:.3}");
    }
    for (name, secs) in report.timer.phases() {
        println!("   {name:<10} {secs:.3} s");
    }

    // --- 2-pass refinement (Algorithm 2) on the SAME pass-1 model ---
    let gen = DigitStream::new(DigitConfig { seed: 2026, ..Default::default() });
    let mut src = GeneratorSource::new(DIGIT_P, n, 2048, move |s, c| gen.chunk(s, c));
    let (two, pass2_secs) = two_pass_refine_stream(&mut src, model, k)?;
    let acc2 = clustering_accuracy(&two.assign, &labels, k);
    println!(
        "[2-pass sparsified] accuracy {acc2:.4}  passes {}  (+{pass2_secs:.1}s refine)",
        report.raw_passes + 1
    );

    // --- native-engine fit: the production CPU hot path, and the
    //     timing anchor for the paper's speedup claim ---
    let gen = DigitStream::new(DigitConfig { seed: 2026, ..Default::default() });
    let mut src = GeneratorSource::new(DIGIT_P, n, 2048, move |s, c| gen.chunk(s, c));
    let native_report = FitPlan::kmeans()
        .stream(&mut src, scfg)
        .k(k)
        .kmeans_opts(opts)
        .assigner(&NativeAssigner)
        .stream_config(stream_cfg)
        .run()?;
    let native_model = native_report.kmeans_model().expect("kmeans plan");
    let acc_native = clustering_accuracy(&native_model.result.assign, &labels, k);
    println!(
        "[1-pass sparsified, engine=native] accuracy {acc_native:.4}  kmeans {:.1}s",
        native_report.timer.get("kmeans")
    );

    // --- full-data K-means baseline (the reference & speedup anchor) ---
    // cap the baseline size so the example stays minutes, not hours
    let n_base = n.min(20_000);
    let base_data = stream.chunk(0, n_base);
    let base_labels = stream.labels(0, n_base);
    let t0 = Instant::now();
    let full = kmeans_dense(&base_data, k, KmeansOpts { n_init: 3, ..opts });
    let t_full = t0.elapsed().as_secs_f64();
    let acc_full = clustering_accuracy(&full.assign, &base_labels, k);
    // per-sample-iteration cost ratio = the paper's speedup metric,
    // measured on the native engine (the CPU production path; the XLA
    // engine trades gamma^-1 extra FLOPs for MXU shape — see DESIGN.md)
    let cost_full =
        t_full / (full.iterations.max(1) * n_base * 3) as f64; // 3 = n_init
    let cost_sparse = native_report.timer.get("kmeans")
        / (native_model.result.iterations.max(1) * n * 3) as f64;
    println!(
        "[full K-means on {n_base} samples] accuracy {acc_full:.4}  iters {}  total {t_full:.1}s",
        full.iterations
    );

    println!("\n=== headline (paper: Table V / Fig 10) ===");
    println!("accuracy: 1-pass {acc1:.4} | 2-pass {acc2:.4} | full-data {acc_full:.4}");
    println!(
        "per-iteration per-sample cost (native): full {:.2} us vs sparsified {:.2} us -> \
         {:.1}x speedup (1/gamma = {:.0}x ideal)",
        cost_full * 1e6,
        cost_sparse * 1e6,
        cost_full / cost_sparse.max(1e-12),
        1.0 / gamma
    );
    // sanity gates so CI catches regressions
    assert!(acc1 > 0.80, "1-pass accuracy regressed: {acc1}");
    assert!(acc2 >= acc1 - 0.02, "2-pass should not be worse: {acc2} vs {acc1}");
    assert!(
        cost_full / cost_sparse.max(1e-12) > 3.0,
        "sparsified iteration should be much cheaper (native engine)"
    );
    println!("end_to_end OK");
    Ok(())
}
