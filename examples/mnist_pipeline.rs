//! Digit-clustering scenario (the paper's §VII.B workload): compare all
//! five algorithms at one compression factor on the digit corpus and
//! print a Fig. 7/8/9-style comparison row, including 1-pass center
//! quality — the property that separates sparsified K-means from the
//! feature-based baselines.
//!
//! Run: `cargo run --release --example mnist_pipeline [n] [gamma]`

use pds::data::{digits, DigitConfig};
use pds::experiments::common::{center_rmse, run_algo, Algo};
use pds::kmeans::KmeansOpts;

fn main() -> pds::Result<()> {
    let mut args = std::env::args().skip(1);
    let n: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(6000);
    let gamma: f64 = args.next().and_then(|s| s.parse().ok()).unwrap_or(0.05);
    println!("digit pipeline: n={n} gamma={gamma} (classes 0/3/9-like, p=784)");

    let d = digits(n, DigitConfig::default());
    let opts = KmeansOpts { n_init: 5, max_iters: 100, tol_frac: 0.0, seed: 0 };

    println!(
        "\n{:<26} {:>9} {:>9} {:>12} {:>7}",
        "algorithm", "accuracy", "time (s)", "center RMSE", "passes"
    );
    for (algo, passes) in [
        (Algo::Sparsified, 1),
        (Algo::SparsifiedNoPrecond, 1),
        (Algo::SparsifiedTwoPass, 2),
        (Algo::FeatureExtraction, 1),
        (Algo::FeatureSelection, 3),
    ] {
        let run = run_algo(algo, &d, 3, gamma, opts, 7)?;
        println!(
            "{:<26} {:>9.4} {:>9.2} {:>12.4} {:>7}",
            algo.name(),
            run.accuracy,
            run.seconds,
            center_rmse(&run.result.centers, &d.centers),
            passes
        );
    }
    println!(
        "\nexpected shape (paper Figs 7-9): sparsified ≥ feature extraction ≫ \
         no-precond; only sparsified has good 1-pass centers"
    );
    Ok(())
}
